//! GF22FDX-calibrated analytical area / power / timing model of one
//! Ara/Sparq lane — the substitution for the paper's Synopsys/Cadence
//! physical implementation (Table II).  See DESIGN.md §2.
//!
//! The model is a component inventory calibrated to the published Ara
//! lane breakdown (the FPU dominates the MFPU; the VRF is an SRAM
//! macro; queues/sequencer are the fixed overhead) such that:
//!
//! * Ara   lane = 0.120 mm², 159.2 mW, 1.346 GHz   (Table II col 1)
//! * Sparq lane = Ara − FPU − FP queue share + vmacsr shifter
//!              = 0.068 mm²,  65.6 mW, 1.464 GHz   (Table II col 2)
//!
//! Frequency is a max-over-paths model: the FPU owns the longest lane
//! path; the vmacsr shifter sits after the SIMD multiplier whose path
//! has slack, so it never sets fmax (the paper's observation).

use crate::arch::ProcessorConfig;

/// One synthesizable component of a lane.
#[derive(Debug, Clone, PartialEq)]
pub struct Component {
    pub name: &'static str,
    /// Cell area in mm² (GF22FDX, post-P&R utilization folded in).
    pub area_mm2: f64,
    /// Power at the typical corner (TT/0.8V/25C), mW, at the lane's fmax.
    pub power_mw: f64,
    /// Critical-path length through this component, ns.
    pub path_ns: f64,
}

/// The calibrated Ara lane inventory (per lane, 4 KiB VRF slice).
fn base_components() -> Vec<Component> {
    vec![
        Component { name: "vrf-sram", area_mm2: 0.0220, power_mw: 18.0, path_ns: 0.580 },
        Component { name: "operand-queues-int", area_mm2: 0.0100, power_mw: 8.0, path_ns: 0.500 },
        Component { name: "operand-queues-fp", area_mm2: 0.0020, power_mw: 3.0, path_ns: 0.500 },
        // the integer multiplier path sets Sparq's fmax once the FPU is
        // gone: 0.683 ns -> 1.464 GHz (Table II)
        Component { name: "simd-multiplier", area_mm2: 0.0140, power_mw: 13.0, path_ns: 0.683 },
        Component { name: "vfpu", area_mm2: 0.0505, power_mw: 90.9, path_ns: 0.743 },
        Component { name: "valu", area_mm2: 0.0090, power_mw: 9.0, path_ns: 0.560 },
        Component { name: "sequencer", area_mm2: 0.0080, power_mw: 10.0, path_ns: 0.620 },
        Component { name: "misc-wiring", area_mm2: 0.0045, power_mw: 7.3, path_ns: 0.400 },
    ]
}

/// The vmacsr shifter (inserted between multiplier and accumulator).
fn vmacsr_shifter() -> Component {
    Component { name: "vmacsr-shifter", area_mm2: 0.0005, power_mw: 0.3, path_ns: 0.660 }
}

/// Physical report for one lane configuration.
#[derive(Debug, Clone)]
pub struct LaneReport {
    pub name: String,
    pub components: Vec<Component>,
    pub lanes: u32,
    pub vrf_kib_total: u32,
}

impl LaneReport {
    /// Build the lane inventory for a processor configuration.
    pub fn for_config(cfg: &ProcessorConfig) -> LaneReport {
        let mut comps: Vec<Component> = base_components();
        if !cfg.fpu {
            comps.retain(|c| c.name != "vfpu" && c.name != "operand-queues-fp");
        }
        if cfg.vmacsr {
            comps.push(vmacsr_shifter());
        }
        // VRF slice scales with per-lane VLEN (Table II config: 4 KiB)
        let slice_kib = cfg.vrf_bytes() as f64 / cfg.lanes as f64 / 1024.0;
        let scale = slice_kib / 4.0;
        for c in comps.iter_mut() {
            if c.name == "vrf-sram" {
                c.area_mm2 *= scale;
                c.power_mw *= scale;
            }
        }
        LaneReport {
            name: cfg.name.clone(),
            components: comps,
            lanes: cfg.lanes,
            vrf_kib_total: cfg.vrf_bytes() / 1024,
        }
    }

    /// Lane cell area, mm².
    pub fn area_mm2(&self) -> f64 {
        self.components.iter().map(|c| c.area_mm2).sum()
    }

    /// Lane power at typical corner, mW.
    pub fn power_mw(&self) -> f64 {
        self.components.iter().map(|c| c.power_mw).sum()
    }

    /// Lane fmax, GHz (max over component paths).
    pub fn fmax_ghz(&self) -> f64 {
        let worst = self.components.iter().map(|c| c.path_ns).fold(0.0, f64::max);
        1.0 / worst
    }

    /// The component owning the critical path.
    pub fn critical_path(&self) -> &Component {
        self.components
            .iter()
            .max_by(|a, b| a.path_ns.partial_cmp(&b.path_ns).unwrap())
            .unwrap()
    }

    /// Whole-vector-engine power (all lanes), mW.
    pub fn total_power_mw(&self) -> f64 {
        self.power_mw() * self.lanes as f64
    }

    /// Energy efficiency at a measured throughput: ops per nanojoule.
    pub fn ops_per_nj(&self, ops_per_cycle: f64) -> f64 {
        let ops_per_s = ops_per_cycle * self.fmax_ghz() * 1e9;
        ops_per_s / (self.total_power_mw() * 1e-3) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn ara_lane_matches_table2() {
        let r = LaneReport::for_config(&ProcessorConfig::ara());
        assert!(close(r.area_mm2(), 0.120, 0.0005), "area {}", r.area_mm2());
        assert!(close(r.power_mw(), 159.2, 0.05), "power {}", r.power_mw());
        assert!(close(r.fmax_ghz(), 1.346, 0.002), "fmax {}", r.fmax_ghz());
        assert_eq!(r.critical_path().name, "vfpu");
    }

    #[test]
    fn sparq_lane_matches_table2() {
        let r = LaneReport::for_config(&ProcessorConfig::sparq());
        assert!(close(r.area_mm2(), 0.068, 0.0005), "area {}", r.area_mm2());
        assert!(close(r.power_mw(), 65.6, 0.05), "power {}", r.power_mw());
        assert!(close(r.fmax_ghz(), 1.464, 0.002), "fmax {}", r.fmax_ghz());
        assert_ne!(r.critical_path().name, "vmacsr-shifter");
    }

    #[test]
    fn paper_deltas() {
        let ara = LaneReport::for_config(&ProcessorConfig::ara());
        let sq = LaneReport::for_config(&ProcessorConfig::sparq());
        let darea = (ara.area_mm2() - sq.area_mm2()) / ara.area_mm2();
        let dpow = (ara.power_mw() - sq.power_mw()) / ara.power_mw();
        let dfreq = (sq.fmax_ghz() - ara.fmax_ghz()) / ara.fmax_ghz();
        assert!(close(darea, 0.433, 0.01), "area delta {darea}"); // paper: -43.3%
        assert!(close(dpow, 0.588, 0.01), "power delta {dpow}"); // paper: -58.8%
        assert!(close(dfreq, 0.087, 0.005), "fmax delta {dfreq}"); // paper: +8.7%
    }

    #[test]
    fn vmacsr_shifter_off_critical_path() {
        // adding the shifter must not change fmax (paper §V-B)
        let mut cfg = ProcessorConfig::ara();
        cfg.vmacsr = true;
        let with = LaneReport::for_config(&cfg);
        let without = LaneReport::for_config(&ProcessorConfig::ara());
        assert_eq!(with.fmax_ghz(), without.fmax_ghz());
    }

    #[test]
    fn vrf_scales_with_vlen() {
        let mut cfg = ProcessorConfig::sparq();
        cfg.vlen_bits *= 2; // 8 KiB per lane
        let r = LaneReport::for_config(&cfg);
        let base = LaneReport::for_config(&ProcessorConfig::sparq());
        assert!(r.area_mm2() > base.area_mm2());
        assert_eq!(r.vrf_kib_total, 32);
    }

    #[test]
    fn efficiency_metric_sane() {
        let r = LaneReport::for_config(&ProcessorConfig::sparq());
        let e = r.ops_per_nj(53.0);
        assert!(e > 0.0 && e.is_finite());
    }
}
