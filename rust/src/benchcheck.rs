//! The CI perf-regression gate: compare the cycle counts in freshly
//! generated `BENCH_*.json` files against the baselines committed
//! under `ci/bench_baselines/`.
//!
//! The simulator's cycle counts are DETERMINISTIC — same sources, same
//! cycles, on any machine — so every numeric field whose key mentions
//! `cycles` is compared at **tolerance 0**: any drift is a perf
//! regression (or an un-blessed intentional change) and fails CI with
//! a printed diff.  Wall-clock fields (`host_*`, `*_s`, throughput)
//! are machine-dependent and are never compared.
//!
//! ## Bless protocol (recorded in ROADMAP.md "Open items")
//!
//! A baseline file containing `"unblessed": true` is a bootstrap
//! placeholder: `bench-check` prints the measured values and passes.
//! To bless (initially, or after an intentional cycle change):
//!
//! 1. `cargo bench --bench <name> -- --json` for every bench (CI's
//!    bench-gate job does exactly this), or run
//!    `sparq bench-check --bless` after generating the files locally;
//! 2. copy the generated `BENCH_*.json` into `ci/bench_baselines/`;
//! 3. commit them with the PR that changed the cycles, so the diff
//!    reviewer sees the perf delta next to the code that caused it.
//!
//! The parser below is a minimal recursive-descent JSON reader (the
//! crate is dependency-free); it accepts the full JSON grammar the
//! bench writer and hand-edited baselines can produce.

use std::fmt;

/// A parsed JSON value (only what the gate needs: numbers keep their
/// f64 value — cycle counts are u64 well below 2^53, so equality is
/// exact).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member lookup on objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

/// Parse a JSON document (typed error with byte offset).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after the document"));
    }
    Ok(v)
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: &'static str,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, message: &'static str) -> ParseError {
        ParseError { offset: self.pos, message }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8, what: &'static str) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(what))
        }
    }

    fn lit(&mut self, s: &'static str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{', "expected '{'")?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':', "expected ':' after member key")?;
            self.ws();
            let v = self.value()?;
            members.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[', "expected '['")?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"', "expected '\"'")?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("invalid \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            self.pos += 4;
                            // BMP only (the bench writer never emits
                            // surrogate pairs); lone surrogates map to
                            // the replacement character
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (the input came from a
                    // &str, so boundaries are valid)
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

/// One divergence between a baseline and the current bench output.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchDiff {
    /// Dotted path of the drifted field (e.g. `sweep.b4.slot_cycles`).
    pub field: String,
    pub baseline: f64,
    /// `None` = the field disappeared from the current output.
    pub current: Option<f64>,
}

impl fmt::Display for BenchDiff {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.current {
            Some(c) => write!(
                f,
                "{}: baseline {} -> current {} ({:+})",
                self.field,
                self.baseline,
                c,
                c - self.baseline
            ),
            None => write!(f, "{}: baseline {} -> MISSING in current output", self.field, self.baseline),
        }
    }
}

/// What comparing one bench file produced.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckOutcome {
    /// The baseline is the `"unblessed": true` bootstrap placeholder:
    /// nothing is gated yet (the bless protocol arms the gate).
    Unblessed,
    /// Every baseline cycle field matched exactly.
    Match { fields: usize },
    /// At least one cycle field drifted (CI fails).
    Drift(Vec<BenchDiff>),
}

/// Is this key a deterministic cycle field (gated at tolerance 0)?
/// Cycle *rates* are excluded: a key like `sim_cycles_per_s` divides
/// deterministic cycles by host wall time, which is machine-dependent
/// and must never be gated.
fn is_cycle_key(key: &str) -> bool {
    let k = key.to_ascii_lowercase();
    k.contains("cycles") && !k.contains("per_s")
}

/// Collect every `(dotted path, value)` gated numeric field,
/// depth-first in document order: a number is gated when its own key
/// names cycles, or when it sits inside an array whose nearest key
/// does (e.g. every element of `"layer_cycles": [..]`).
pub fn cycle_fields(doc: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    collect(doc, "", false, &mut out);
    out
}

fn collect(v: &Json, path: &str, gated: bool, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(n) => {
            if gated {
                out.push((path.to_string(), *n));
            }
        }
        Json::Obj(members) => {
            for (k, v) in members {
                let sub = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
                // an object member's own key decides its gating
                collect(v, &sub, is_cycle_key(k), out);
            }
        }
        Json::Arr(items) => {
            // array elements have no key: they inherit the array's
            for (i, v) in items.iter().enumerate() {
                collect(v, &format!("{path}[{i}]"), gated, out);
            }
        }
        _ => {}
    }
}

/// Is this baseline the `"unblessed": true` bootstrap placeholder?
pub fn is_unblessed(baseline: &Json) -> bool {
    matches!(baseline.get("unblessed"), Some(Json::Bool(true)))
}

/// Compare one baseline document against the current bench output:
/// every cycle field in the BASELINE must exist in the current output
/// with exactly the same value (cycles are deterministic — tolerance
/// 0).  Fields only present in the current output are new benches'
/// data and pass (they gate once blessed).
pub fn compare(baseline: &Json, current: &Json) -> CheckOutcome {
    if is_unblessed(baseline) {
        return CheckOutcome::Unblessed;
    }
    let base = cycle_fields(baseline);
    let cur: std::collections::HashMap<String, f64> = cycle_fields(current).into_iter().collect();
    let mut diffs = Vec::new();
    for (field, bval) in &base {
        match cur.get(field) {
            Some(&cval) if cval == *bval => {}
            Some(&cval) => {
                diffs.push(BenchDiff { field: field.clone(), baseline: *bval, current: Some(cval) })
            }
            None => diffs.push(BenchDiff { field: field.clone(), baseline: *bval, current: None }),
        }
    }
    if diffs.is_empty() {
        CheckOutcome::Match { fields: base.len() }
    } else {
        CheckOutcome::Drift(diffs)
    }
}

/// Compare two raw JSON texts (convenience for the CLI and tests).
pub fn compare_texts(baseline: &str, current: &str) -> Result<CheckOutcome, ParseError> {
    Ok(compare(&parse(baseline)?, &parse(current)?))
}

/// The bench files the gate knows about (name, artifact filename).
pub const BENCH_FILES: [&str; 6] = [
    "BENCH_simspeed.json",
    "BENCH_qnn.json",
    "BENCH_mixed.json",
    "BENCH_serve.json",
    "BENCH_topo.json",
    "BENCH_cluster.json",
];

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"{
        "bench": "serve_throughput",
        "fmax_ghz": 1.464,
        "sweep": {
            "b1": {"slot_cycles": 41000, "preamble_cycles": 27648, "host_images_per_s": 812.5},
            "b8": {"slot_cycles": 41000, "preamble_cycles": 27648, "cycles_per_image": 44456.0}
        },
        "serve": {"p50_cycles": 41000, "completed": 48, "sim_cycles_per_s": 3.1e9}
    }"#;

    #[test]
    fn parser_roundtrips_the_bench_writer_grammar() {
        let doc = parse(BASE).unwrap();
        assert!(matches!(doc.get("bench"), Some(Json::Str(s)) if s == "serve_throughput"));
        let fields = cycle_fields(&doc);
        // host_images_per_s and completed are NOT cycle fields, and
        // neither is the wall-derived rate sim_cycles_per_s
        let names: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "sweep.b1.slot_cycles",
                "sweep.b1.preamble_cycles",
                "sweep.b8.slot_cycles",
                "sweep.b8.preamble_cycles",
                "sweep.b8.cycles_per_image",
                "serve.p50_cycles",
            ]
        );
        assert!(parse("{\"a\": [1, 2, {\"cycles\": 3}]}").is_ok());
        assert!(parse("not json").is_err());
        assert!(parse("{\"a\": 1} trailing").is_err());
    }

    #[test]
    fn array_elements_under_a_cycle_key_are_gated() {
        // the documented contract covers numbers inside cycle-named
        // arrays too ("layer_cycles": [..]) — and drift in one element
        // fails the gate
        let base = r#"{"layer_cycles": [4100, 5200], "host_s": [0.5, 0.6]}"#;
        let fields = cycle_fields(&parse(base).unwrap());
        let names: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["layer_cycles[0]", "layer_cycles[1]"]);
        let drift = base.replace("5200", "5201");
        assert!(matches!(compare_texts(base, &drift).unwrap(), CheckOutcome::Drift(_)));
        // a number inside an object inside a cycle-named key is judged
        // by its OWN key (deep wall fields stay ungated)
        let nested = r#"{"cycles_by_layer": {"stem": 10, "host_s": 0.5}}"#;
        let f = cycle_fields(&parse(nested).unwrap());
        assert!(f.is_empty(), "object members are gated by their own keys: {f:?}");
    }

    #[test]
    fn identical_documents_match_on_every_cycle_field() {
        match compare_texts(BASE, BASE).unwrap() {
            CheckOutcome::Match { fields } => assert_eq!(fields, 6),
            other => panic!("expected a match, got {other:?}"),
        }
    }

    #[test]
    fn a_drifted_cycle_count_fails_the_gate() {
        // the acceptance demonstration: one drifted cycle field makes
        // the gate fail with a printed diff — this is what makes CI red
        let current = BASE.replace("\"slot_cycles\": 41000", "\"slot_cycles\": 41001");
        match compare_texts(BASE, &current).unwrap() {
            CheckOutcome::Drift(diffs) => {
                assert_eq!(diffs.len(), 2, "both b1 and b8 slot_cycles drifted");
                assert_eq!(diffs[0].field, "sweep.b1.slot_cycles");
                assert_eq!(diffs[0].baseline, 41000.0);
                assert_eq!(diffs[0].current, Some(41001.0));
                assert!(diffs[0].to_string().contains("41001"));
            }
            other => panic!("drift must fail the gate, got {other:?}"),
        }
    }

    #[test]
    fn tolerance_is_zero_on_cycles_and_wall_fields_are_ignored() {
        // wall-clock drift passes (throughput AND cycle-rate fields);
        // a 1-cycle drift does not
        let wall_drift = BASE.replace("812.5", "9999.0").replace("3.1e9", "2.2e9");
        assert!(matches!(
            compare_texts(BASE, &wall_drift).unwrap(),
            CheckOutcome::Match { .. }
        ));
        let cyc_drift = BASE.replace("\"p50_cycles\": 41000", "\"p50_cycles\": 40999");
        assert!(matches!(compare_texts(BASE, &cyc_drift).unwrap(), CheckOutcome::Drift(_)));
    }

    #[test]
    fn missing_cycle_field_is_a_drift() {
        let current = BASE.replace("\"p50_cycles\": 41000, ", "");
        match compare_texts(BASE, &current).unwrap() {
            CheckOutcome::Drift(diffs) => {
                assert!(diffs.iter().any(|d| d.field == "serve.p50_cycles" && d.current.is_none()));
            }
            other => panic!("missing field must fail, got {other:?}"),
        }
    }

    #[test]
    fn unblessed_baselines_bootstrap_without_gating() {
        let placeholder = r#"{"unblessed": true, "note": "bless me"}"#;
        assert_eq!(compare_texts(placeholder, BASE).unwrap(), CheckOutcome::Unblessed);
    }

    #[test]
    fn new_fields_in_current_output_do_not_fail() {
        let grown = BASE.replace(
            "\"completed\": 48",
            "\"completed\": 48, \"p99_cycles\": 41000",
        );
        assert!(matches!(compare_texts(BASE, &grown).unwrap(), CheckOutcome::Match { .. }));
    }
}
