//! Experiment drivers + paper-style renderers: every table and figure
//! of the paper regenerates through this module (the CLI subcommands
//! and the cargo benches are thin wrappers around these functions).
//!
//! Beyond the paper's figures, the module carries the scale-out
//! capacity planning sweep: [`capacity_grid`] runs the full
//! (cores × batch × precision) grid through the K-core cluster layer
//! (`coordinator::cluster`, DESIGN.md §Cluster) and renders it as one
//! capacity table ([`render_capacity`]) — the single-core
//! [`throughput_sweep`] is its K=1, W2A2 column.  Every cycle number
//! in the grid is deterministic simulated arithmetic (max-over-cores
//! makespan + a fixed shard/merge overhead), so the grid is gated at
//! tolerance 0 by `rust/benches/cluster.rs` → BENCH_cluster.json.

use crate::arch::{ProcessorConfig, Unit};
use crate::kernels::{
    run_conv_cached, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload,
};
use crate::power::LaneReport;
use crate::qnn::{schedule, QnnGraph};
use crate::qnn::schedule::QnnPrecision;
use crate::sim::{MachinePool, RunReport, SimError};
use crate::ulppack::{region, RegionMode};

/// Shared compile-once/execute-many context for the sweep drivers: one
/// program cache + one machine pool reused across figures so repeated
/// (workload, variant, processor) tuples stop re-emitting identical
/// instruction streams.  The benches run each figure twice (cold/warm)
/// against one `SweepCtx` to demonstrate the cached speedup.
///
/// Both members are `Sync`, so the figure drivers fan their
/// independent workloads out across `std::thread::scope` threads that
/// share this context — each thread executes pre-compiled micro-op
/// programs (`sim::CompiledProgram`) on pooled machines, and the
/// deterministic simulator makes the parallel sweep bit-identical to
/// the sequential one.
#[derive(Default)]
pub struct SweepCtx {
    pub cache: ProgramCache,
    pub pool: MachinePool,
}

impl SweepCtx {
    pub fn new() -> SweepCtx {
        SweepCtx::default()
    }

    /// Run one conv through the context (cycle counts and outputs are
    /// bit-identical to `kernels::run_conv`).
    pub fn run(
        &self,
        cfg: &ProcessorConfig,
        wl: &Workload,
        variant: ConvVariant,
    ) -> Result<RunReport, SimError> {
        run_conv_cached(&self.cache, &self.pool, cfg, wl, variant, EngineOpts::default())
    }
}

/// One bar of Fig. 4.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub label: String,
    pub cycles: u64,
    pub ops_per_cycle: f64,
    pub speedup_vs_int16: f64,
    pub mfpu_util: f64,
}

/// Fig. 4: ops/cycle for every conv2d implementation, 7x7 kernel.
pub fn fig4(large: bool, seed: u64) -> Result<Vec<Fig4Row>, SimError> {
    fig4_with(&SweepCtx::new(), large, seed)
}

/// [`fig4`] against a caller-held [`SweepCtx`] (warm reruns are pure
/// cache hits).
///
/// §Perf: the six implementations are independent workloads, so they
/// run in parallel (`std::thread::scope`) against the shared program
/// cache and machine pool.  Rows keep the plan order and each run is
/// deterministic, so the figure is bit-identical to a sequential
/// sweep.
pub fn fig4_with(ctx: &SweepCtx, large: bool, seed: u64) -> Result<Vec<Fig4Row>, SimError> {
    let dims = ConvDims::fig4(large);
    let sparq = ProcessorConfig::sparq();
    let ara = ProcessorConfig::ara();
    // paper legend order: int16, W3A3, W2A2, W1A1 (native), LP, ULP
    let plan: Vec<(&ProcessorConfig, ConvVariant, String)> = vec![
        (&sparq, ConvVariant::Int16, "int16-conv2d".into()),
        (&ara, ConvVariant::Native { w_bits: 3, a_bits: 3 }, "W3A3-conv2d".into()),
        (&ara, ConvVariant::Native { w_bits: 2, a_bits: 2 }, "W2A2-conv2d".into()),
        (&ara, ConvVariant::Native { w_bits: 1, a_bits: 1 }, "W1A1-conv2d".into()),
        (
            &sparq,
            ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Paper },
            "LP-conv2d (vmacsr, W4A4)".into(),
        ),
        (
            &sparq,
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper },
            "ULP-conv2d (vmacsr, W2A2)".into(),
        ),
    ];
    let reports: Vec<Result<RunReport, SimError>> = std::thread::scope(|s| {
        let handles: Vec<_> = plan
            .iter()
            .map(|(cfg, variant, _)| {
                s.spawn(move || {
                    let (wb, ab) = variant.bits();
                    let wl = Workload::random(dims, wb, ab, seed);
                    ctx.run(cfg, &wl, *variant)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
    });
    let mut rows = Vec::new();
    let mut base_cycles = 0u64;
    for ((_, _, label), report) in plan.into_iter().zip(reports) {
        let report = report?;
        if rows.is_empty() {
            base_cycles = report.stats.cycles;
        }
        rows.push(Fig4Row {
            label,
            cycles: report.stats.cycles,
            ops_per_cycle: report.ops_per_cycle(),
            speedup_vs_int16: base_cycles as f64 / report.stats.cycles as f64,
            mfpu_util: report.stats.utilization(Unit::Mfpu),
        });
    }
    Ok(rows)
}

pub fn render_fig4(rows: &[Fig4Row], dims: ConvDims) -> String {
    let mut s = format!(
        "Fig. 4 — conv2d performance, {}x{}x{} input, {}x{} kernel, 4 lanes\n\
         {:<28} {:>12} {:>10} {:>9} {:>7}\n",
        dims.c, dims.h, dims.w, dims.fh, dims.fw, "implementation", "cycles", "ops/cycle", "speedup", "MFPU"
    );
    let maxops = rows.iter().map(|r| r.ops_per_cycle).fold(0.0, f64::max);
    for r in rows {
        let bar = "#".repeat(((r.ops_per_cycle / maxops) * 30.0).round() as usize);
        s += &format!(
            "{:<28} {:>12} {:>10.2} {:>8.2}x {:>6.1}%  {}\n",
            r.label,
            r.cycles,
            r.ops_per_cycle,
            r.speedup_vs_int16,
            100.0 * r.mfpu_util,
            bar
        );
    }
    s
}

/// One cell of Fig. 5: speedup over int16 at (W, A), if runnable.
#[derive(Debug, Clone, Copy)]
pub struct Fig5Cell {
    pub w_bits: u32,
    pub a_bits: u32,
    pub speedup: Option<f64>,
    pub container: Option<&'static str>,
}

/// Fig. 5: the speedup grid over the precision region, native (a) or
/// vmacsr (b).
pub fn fig5(vmacsr: bool, large: bool, seed: u64) -> Result<Vec<Fig5Cell>, SimError> {
    fig5_with(&SweepCtx::new(), vmacsr, large, seed)
}

/// [`fig5`] against a caller-held [`SweepCtx`]: the int16 baseline is
/// shared between the 5a and 5b grids (one compile instead of two), and
/// warm reruns are pure cache hits.
///
/// §Perf: after the shared baseline, the 16 grid points run in
/// parallel on pooled machines; cells keep (W, A) order, so the
/// rendered grid is identical to the sequential sweep.
pub fn fig5_with(
    ctx: &SweepCtx,
    vmacsr: bool,
    large: bool,
    seed: u64,
) -> Result<Vec<Fig5Cell>, SimError> {
    let dims = ConvDims::fig5(large);
    let sparq = ProcessorConfig::sparq();
    let ara = ProcessorConfig::ara();
    let wl16 = Workload::random(dims, 8, 8, seed);
    let base = ctx.run(&sparq, &wl16, ConvVariant::Int16)?;
    let base_cycles = base.stats.cycles;
    let points: Vec<(u32, u32)> =
        (1..=4u32).flat_map(|w| (1..=4u32).map(move |a| (w, a))).collect();
    let cells: Vec<Result<Fig5Cell, SimError>> = std::thread::scope(|s| {
        let handles: Vec<_> = points
            .iter()
            .map(|&(w, a)| {
                let (sparq, ara) = (&sparq, &ara);
                s.spawn(move || {
                    let (variant, cfg, plan) = if vmacsr {
                        (
                            ConvVariant::Vmacsr { w_bits: w, a_bits: a, mode: RegionMode::Paper },
                            sparq,
                            region::plan_vmacsr(w, a, dims.issues_per_output(), RegionMode::Paper),
                        )
                    } else {
                        (ConvVariant::Native { w_bits: w, a_bits: a }, ara, region::plan_native(w, a))
                    };
                    match plan {
                        None => Ok(Fig5Cell { w_bits: w, a_bits: a, speedup: None, container: None }),
                        Some(p) => {
                            let wl =
                                Workload::random(dims, w, a, seed.wrapping_add((w * 5 + a) as u64));
                            let report = ctx.run(cfg, &wl, variant)?;
                            Ok(Fig5Cell {
                                w_bits: w,
                                a_bits: a,
                                speedup: Some(base_cycles as f64 / report.stats.cycles as f64),
                                container: Some(p.container.name()),
                            })
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
    });
    cells.into_iter().collect()
}

pub fn render_fig5(cells: &[Fig5Cell], vmacsr: bool, dims: ConvDims) -> String {
    let mut s = format!(
        "Fig. 5{} — speedup over int16-conv2d, {} implementation\n\
         ({}x{}x{} input, {}x{} kernel; '--' = outside the overflow-free region)\n\n      ",
        if vmacsr { "b" } else { "a" },
        if vmacsr { "vmacsr (Sparq)" } else { "native RVV (Ara)" },
        dims.c, dims.h, dims.w, dims.fh, dims.fw
    );
    for a in 1..=4 {
        s += &format!("   A{a}      ");
    }
    s += "\n";
    for w in 1..=4u32 {
        s += &format!("  W{w}  ");
        for a in 1..=4u32 {
            let cell = cells.iter().find(|c| c.w_bits == w && c.a_bits == a).unwrap();
            match cell.speedup {
                Some(sp) => s += &format!("{:>5.2}x {:<3} ", sp, cell.container.unwrap_or("")),
                None => s += &format!("{:>9} ", "--"),
            }
        }
        s += "\n";
    }
    s
}

/// Table II rows.
pub fn table2() -> (LaneReport, LaneReport) {
    (
        LaneReport::for_config(&ProcessorConfig::ara()),
        LaneReport::for_config(&ProcessorConfig::sparq()),
    )
}

pub fn render_table2(ara: &LaneReport, sparq: &LaneReport) -> String {
    let mut s = String::from(
        "Table II — physical implementation of Ara and Sparq lanes (GF22FDX model)\n\
         (at typical corner TT/0.8V/25C)\n\n",
    );
    s += &format!("{:<28} {:>10} {:>10}\n", "", "Ara Lane", "Sparq Lane");
    s += &format!("{:<28} {:>10} {:>10}\n", "Number of Lanes", ara.lanes, sparq.lanes);
    s += &format!("{:<28} {:>10} {:>10}\n", "VRF Size [KiB]", ara.vrf_kib_total, sparq.vrf_kib_total);
    s += &format!(
        "{:<28} {:>10.3} {:>10.3}\n",
        "Lane Cell Area [mm2]",
        ara.area_mm2(),
        sparq.area_mm2()
    );
    s += &format!(
        "{:<28} {:>10.3} {:>10.3}\n",
        "Lane Core Frequency [GHz]",
        ara.fmax_ghz(),
        sparq.fmax_ghz()
    );
    s += &format!("{:<28} {:>10.1} {:>10.1}\n", "Lane Power [mW]", ara.power_mw(), sparq.power_mw());
    s += &format!(
        "\ndeltas: area {:+.1}%, power {:+.1}%, fmax {:+.1}% (paper: -43.3%, -58.8%, +8.7%)\n",
        100.0 * (sparq.area_mm2() / ara.area_mm2() - 1.0),
        100.0 * (sparq.power_mw() / ara.power_mw() - 1.0),
        100.0 * (sparq.fmax_ghz() / ara.fmax_ghz() - 1.0)
    );
    s += &format!("critical path: Ara = {}, Sparq = {}\n", ara.critical_path().name, sparq.critical_path().name);
    s
}

/// §III-A lane-utilization reproduction: int16 on Sparq, fp32 on Ara
/// (the two baselines run in parallel on pooled machines).
pub fn utilization(large: bool, seed: u64) -> Result<Vec<(String, f64, u64)>, SimError> {
    let ctx = SweepCtx::new();
    let s = if large { 512 } else { 128 };
    let dims = ConvDims { c: 32, h: s + 6, w: s + 6, co: 2, fh: 7, fw: 7 };
    let wl = Workload::random(dims, 8, 8, seed);
    let (int16, fp32) = std::thread::scope(|s| {
        let h16 = s.spawn(|| ctx.run(&ProcessorConfig::sparq(), &wl, ConvVariant::Int16));
        let h32 = s.spawn(|| ctx.run(&ProcessorConfig::ara(), &wl, ConvVariant::Fp32));
        (h16.join().expect("int16 worker"), h32.join().expect("fp32 worker"))
    });
    let (int16, fp32) = (int16?, fp32?);
    Ok(vec![
        ("int16 (Sparq)".to_string(), int16.stats.utilization(Unit::Mfpu), int16.stats.cycles),
        ("fp32 (Ara)".to_string(), fp32.stats.utilization(Unit::Mfpu), fp32.stats.cycles),
    ])
}

pub fn render_utilization(rows: &[(String, f64, u64)], large: bool) -> String {
    let sz = if large { "1x32x512x512" } else { "1x32x128x128" };
    let mut s = format!(
        "§III-A — lane (MFPU) utilization at {sz} (paper: int16 93.8%, fp32 93.6% at 512x512)\n"
    );
    for (label, util, cycles) in rows {
        s += &format!("  {:<16} {:>6.1}%   ({} cycles)\n", label, util * 100.0, cycles);
    }
    s
}

/// Table I substitution: accuracy of the trained QNN artifacts (read
/// back from the manifest + evaluated through PJRT by the caller, who
/// has the runtime; this renders the rows).
pub fn render_table1(rows: &[(String, f64, f64)]) -> String {
    let mut s = String::from(
        "Table I (substitution) — SparqCNN accuracy on the synthetic dataset\n\
         (paper's point: 3-4-bit QNNs match or beat FP32; see DESIGN.md §2)\n\n",
    );
    s += &format!("{:<10} {:>12} {:>14}\n", "precision", "accuracy", "vs fp32");
    for (name, acc, delta) in rows {
        s += &format!("{:<10} {:>11.2}% {:>+13.2}%\n", name, acc * 100.0, delta * 100.0);
    }
    s
}

/// The QNN cycle schedule table (per-layer cost read off one real
/// end-to-end dataflow run for sub-byte precisions).
pub fn render_schedule(s: &crate::qnn::QnnSchedule, fmax_ghz: f64) -> String {
    let mut out = format!(
        "QNN schedule — {} layers at {} on {} (weight seed {:#x})\n{:<26} {:>12} {:>12} {:>22}\n",
        s.layers.len(),
        s.precision.label(),
        s.processor,
        s.seed,
        "layer",
        "cycles",
        "macs",
        "variant"
    );
    for l in &s.layers {
        out += &format!("{:<26} {:>12} {:>12} {:>22}\n", l.name, l.cycles, l.macs, l.variant);
    }
    out += &format!(
        "total: {} cycles/image -> {:.0} images/s at {:.3} GHz\n",
        s.total_cycles(),
        s.throughput_at(fmax_ghz),
        fmax_ghz
    );
    out
}

/// One rung of the precision ladder: a (graph, precision)
/// configuration scheduled end-to-end.
#[derive(Debug, Clone)]
pub struct LadderRow {
    pub label: String,
    pub schedule: crate::qnn::QnnSchedule,
}

/// The precision-ladder configurations: the SparqCNN at every uniform
/// sub-byte precision `w1a1`..`w4a4`, the mixed stem/head
/// configurations (higher-precision stem-adjacent conv over a
/// lower-precision deep conv, and the reverse), and the three DAG
/// topologies (residual, depthwise+pointwise, dense-head) at the W2A2
/// base precision.  The single source of truth the report sweep AND
/// `rust/benches/mixed_precision.rs` build from, so the two can never
/// cover different rungs under the same labels.
pub fn ladder_configs() -> Vec<(String, QnnGraph, QnnPrecision)> {
    let mut configs: Vec<(String, QnnGraph, QnnPrecision)> = (1..=4u32)
        .map(|b| {
            (
                format!("w{b}a{b}"),
                QnnGraph::sparq_cnn(),
                QnnPrecision::SubByte { w_bits: b, a_bits: b },
            )
        })
        .collect();
    let base = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    configs.push((
        "mixed w4a4-stem/w2a2".into(),
        QnnGraph::sparq_cnn_mixed((4, 4), (2, 2)),
        base,
    ));
    configs.push((
        "mixed w2a2-stem/w4a4".into(),
        QnnGraph::sparq_cnn_mixed((2, 2), (4, 4)),
        base,
    ));
    configs.push(("resnetlike w2a2".into(), QnnGraph::sparq_resnetlike(), base));
    configs.push(("mobilenetlike w2a2".into(), QnnGraph::sparq_mobilenetlike(), base));
    configs.push(("denselike w2a2".into(), QnnGraph::sparq_denselike(), base));
    configs
}

/// The precision-ladder sweep over [`ladder_configs`].  Every rung
/// runs the real autotuned dataflow program through the shared
/// [`SweepCtx`] cache — tune rankings are memoized per layer shape, so
/// the whole ladder re-measures nothing it has already seen.
pub fn precision_ladder(ctx: &SweepCtx) -> Result<Vec<LadderRow>, SimError> {
    let cfg = ProcessorConfig::sparq();
    let mut rows = Vec::new();
    for (label, graph, prec) in ladder_configs() {
        let schedule =
            crate::qnn::schedule::schedule_cached(&cfg, &graph, prec, &ctx.cache, &ctx.pool)?;
        rows.push(LadderRow { label, schedule });
    }
    Ok(rows)
}

pub fn render_ladder(rows: &[LadderRow], fmax_ghz: f64) -> String {
    let mut s = format!(
        "Precision ladder — SparqCNN end-to-end (autotuned per-layer kernels, {:.3} GHz)\n\
         {:<22} {:>12} {:>12} {:>10}\n",
        fmax_ghz, "configuration", "cycles/img", "img/s", "speedup"
    );
    let base = rows
        .iter()
        .find(|r| r.label == "w4a4")
        .map(|r| r.schedule.total_cycles())
        .unwrap_or_else(|| rows[0].schedule.total_cycles());
    for r in rows {
        let cyc = r.schedule.total_cycles();
        s += &format!(
            "{:<22} {:>12} {:>12.0} {:>9.2}x\n",
            r.label,
            cyc,
            r.schedule.throughput_at(fmax_ghz),
            base as f64 / cyc as f64
        );
    }
    s += "\nper-layer kernel choices:\n";
    for r in rows {
        s += &format!("  {}:\n", r.label);
        for l in &r.schedule.layers {
            s += &format!("    {:<26} {:>12} cycles  {}\n", l.name, l.cycles, l.variant);
        }
    }
    s
}

/// One rung of the batched-serving throughput sweep
/// ([`throughput_sweep`]).
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    /// Activation slots per batched execution.
    pub batch: u32,
    /// Per-slot chained-stage cycles — batch-invariant (bit-identical
    /// to a one-image execution).
    pub slot_cycles: u64,
    /// Per-batch weight-pack preamble cycles, paid once per execution
    /// however full the batch is.
    pub preamble_cycles: u64,
    /// Amortized simulated cycles per image at full batches:
    /// `slot + preamble / B` — strictly decreasing in B.
    pub cycles_per_image: f64,
    /// Images/second at the lane fmax, full batches.
    pub img_per_s_fmax: f64,
    /// Host-side wall throughput over the sweep's executions
    /// (informational; machine-dependent, not gated).
    pub wall_img_per_s: f64,
}

/// Batched-serving throughput sweep (DESIGN.md §Serving): the SparqCNN
/// at W2A2 compiled under the batch-B arena layout for every requested
/// batch size, each serving `images` distinct images in full batches
/// through the shared [`SweepCtx`] cache.  Simulated img/s comes from
/// the deterministic cycle arithmetic (per-slot cycles are
/// batch-invariant; only the per-batch weight-pack preamble amortizes),
/// so the B=1..B=8 ordering is exact and CI-gateable; wall img/s is
/// measured alongside for the host-side picture.  Warm reruns are pure
/// graph-level cache hits — nothing recompiles, nothing re-tunes.
///
/// Since the cluster layer landed this is the K=1, W2A2 column of
/// [`capacity_grid`]: a 1-core cluster pays zero shard/merge overhead,
/// so the delegation is value-identical to the original single-model
/// sweep.
pub fn throughput_sweep(
    ctx: &SweepCtx,
    batches: &[u32],
    images: usize,
) -> Result<Vec<ThroughputRow>, SimError> {
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let rows = capacity_grid(ctx, &[1], batches, &[("w2a2", prec)], images)?;
    Ok(rows
        .into_iter()
        .map(|r| ThroughputRow {
            batch: r.batch,
            slot_cycles: r.slot_cycles,
            preamble_cycles: r.preamble_cycles,
            cycles_per_image: r.cycles_per_image,
            img_per_s_fmax: r.img_per_s_fmax,
            wall_img_per_s: r.wall_img_per_s,
        })
        .collect())
}

/// One cell of the (cores × batch × precision) capacity grid
/// ([`capacity_grid`]).
#[derive(Debug, Clone)]
pub struct CapacityRow {
    /// Cluster width the frame was sharded across.
    pub cores: usize,
    /// Activation slots per dispatched frame.
    pub batch: u32,
    /// Precision label (e.g. `w2a2`).
    pub precision: String,
    /// Per-slot chained-stage cycles — batch- AND core-invariant.
    pub slot_cycles: u64,
    /// Per-execution weight-pack preamble cycles (each core that
    /// executes a shard pays it once).
    pub preamble_cycles: u64,
    /// Fixed shard/merge overhead
    /// (`coordinator::cluster::shard_merge_overhead`; zero at K=1).
    pub overhead_cycles: u64,
    /// Cluster makespan of one full frame: max over cores of per-core
    /// cycles, plus the overhead.
    pub makespan_cycles: u64,
    /// Amortized simulated cycles per image: `makespan / B`.
    pub cycles_per_image: f64,
    /// Cluster images/second at the lane fmax, full frames.
    pub img_per_s_fmax: f64,
    /// Host-side wall throughput (informational; machine-dependent,
    /// not gated).
    pub wall_img_per_s: f64,
}

/// The full (cores × batch × precision) capacity-planning grid
/// (DESIGN.md §Cluster): for every precision and batch size the
/// SparqCNN compiles once under the batch-B arena layout (shared
/// [`SweepCtx`] cache), then every requested cluster width serves the
/// same full frames through a round-robin
/// [`crate::coordinator::cluster::QnnCluster`].  The makespan is
/// deterministic (max-over-cores + fixed shard/merge overhead), so
/// every cycle column is exact and CI-gateable; for a fixed batch
/// B >= K the makespan strictly shrinks as cores are added (fewer
/// slots per core dominate the small linear overhead), so cluster
/// img/s strictly increases — asserted in `rust/benches/cluster.rs`.
pub fn capacity_grid(
    ctx: &SweepCtx,
    cores: &[usize],
    batches: &[u32],
    precisions: &[(&str, QnnPrecision)],
    images: usize,
) -> Result<Vec<CapacityRow>, SimError> {
    use crate::coordinator::cluster::{QnnCluster, ShardPolicy};
    use crate::qnn::schedule::DEFAULT_QNN_SEED;
    use crate::runtime::SimQnnModel;
    use std::sync::Arc;
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let graph = QnnGraph::sparq_cnn();
    let mut rows = Vec::with_capacity(cores.len() * batches.len() * precisions.len());
    for &(plabel, prec) in precisions {
        for &b in batches {
            let model = Arc::new(SimQnnModel::compile_batched(
                &cfg,
                &graph,
                prec,
                DEFAULT_QNN_SEED,
                &ctx.cache,
                b,
            )?);
            let inputs: Vec<Vec<f32>> = (0..images.max(b as usize))
                .map(|i| {
                    (0..model.input_len())
                        .map(|k| ((k as u64 * 31 + i as u64) % 4) as f32)
                        .collect()
                })
                .collect();
            for &k in cores {
                let cluster = QnnCluster::new(Arc::clone(&model), k, ShardPolicy::RoundRobin);
                let mut slot_cycles = None;
                let mut preamble_cycles = 0u64;
                let mut overhead_cycles = 0u64;
                let mut makespan_cycles = 0u64;
                let mut served = 0usize;
                let t0 = std::time::Instant::now();
                for chunk in inputs.chunks(b as usize) {
                    if chunk.len() < b as usize {
                        break; // full frames only: the grid measures fill = B
                    }
                    let refs: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
                    let run = cluster.infer_frame(&refs)?;
                    for res in &run.results {
                        let (_, cyc) = res.as_ref().expect("clean cluster run");
                        match slot_cycles {
                            None => slot_cycles = Some(*cyc),
                            Some(s) => {
                                debug_assert_eq!(s, *cyc, "slot cycles must be layout-invariant")
                            }
                        }
                    }
                    served += run.results.len();
                    // full frames under round-robin: the account is
                    // frame-invariant, keep the last one
                    overhead_cycles = run.account.overhead_cycles;
                    makespan_cycles = run.account.makespan_cycles;
                    let busiest = run
                        .account
                        .per_core
                        .iter()
                        .max_by_key(|c| c.cycles)
                        .expect("cluster has cores");
                    let slot = slot_cycles.unwrap_or(0);
                    preamble_cycles =
                        busiest.cycles - busiest.requests as u64 * slot;
                }
                let wall = t0.elapsed().as_secs_f64();
                let slot = slot_cycles.expect("at least one full frame must run");
                let cycles_per_image = makespan_cycles as f64 / b as f64;
                rows.push(CapacityRow {
                    cores: cluster.cores(),
                    batch: b,
                    precision: plabel.to_string(),
                    slot_cycles: slot,
                    preamble_cycles,
                    overhead_cycles,
                    makespan_cycles,
                    cycles_per_image,
                    img_per_s_fmax: fmax * 1e9 / cycles_per_image,
                    wall_img_per_s: if wall > 0.0 { served as f64 / wall } else { 0.0 },
                });
            }
        }
    }
    Ok(rows)
}

/// Render the capacity grid as one table (grouped precision → batch →
/// cores, the order [`capacity_grid`] emits).
pub fn render_capacity(rows: &[CapacityRow], fmax_ghz: f64) -> String {
    let mut s = format!(
        "Cluster capacity — SparqCNN, full frames at {:.3} GHz (round-robin shard; \
         makespan = max over cores + fixed shard/merge overhead)\n\
         {:>6} {:>5} {:>6} {:>12} {:>10} {:>9} {:>12} {:>12} {:>12} {:>12}\n",
        fmax_ghz,
        "prec",
        "B",
        "cores",
        "slot cyc",
        "preamble",
        "overhead",
        "makespan",
        "cyc/img",
        "img/s@fmax",
        "host img/s"
    );
    for r in rows {
        s += &format!(
            "{:>6} {:>5} {:>6} {:>12} {:>10} {:>9} {:>12} {:>12.1} {:>12.0} {:>12.0}\n",
            r.precision,
            r.batch,
            r.cores,
            r.slot_cycles,
            r.preamble_cycles,
            r.overhead_cycles,
            r.makespan_cycles,
            r.cycles_per_image,
            r.img_per_s_fmax,
            r.wall_img_per_s
        );
    }
    s
}

pub fn render_throughput(rows: &[ThroughputRow], fmax_ghz: f64) -> String {
    let mut s = format!(
        "Batched serving throughput — SparqCNN W2A2, full batches at {:.3} GHz\n\
         (per-slot cycles are batch-invariant; the per-batch weight-pack preamble amortizes)\n\
         {:>5} {:>12} {:>12} {:>14} {:>12} {:>14}\n",
        fmax_ghz, "B", "slot cyc", "preamble", "cyc/img", "img/s@fmax", "host img/s"
    );
    for r in rows {
        s += &format!(
            "{:>5} {:>12} {:>12} {:>14.1} {:>12.0} {:>14.0}\n",
            r.batch,
            r.slot_cycles,
            r.preamble_cycles,
            r.cycles_per_image,
            r.img_per_s_fmax,
            r.wall_img_per_s
        );
    }
    s
}

/// Re-export for the schedule driver: one-shot schedule of the
/// SparqCNN (sub-byte precisions run the real end-to-end dataflow
/// program; see `qnn::schedule`).
pub fn qnn_schedule(
    cfg: &ProcessorConfig,
    precision: QnnPrecision,
) -> Result<crate::qnn::QnnSchedule, SimError> {
    schedule(cfg, &QnnGraph::sparq_cnn(), precision)
}

/// [`qnn_schedule`] against a caller-held [`SweepCtx`]: the compiled
/// network is fetched from the shared cache (graph-level key) and the
/// readout inference runs on a pooled machine — warm reruns compile
/// nothing.
pub fn qnn_schedule_with(
    ctx: &SweepCtx,
    cfg: &ProcessorConfig,
    precision: QnnPrecision,
) -> Result<crate::qnn::QnnSchedule, SimError> {
    crate::qnn::schedule::schedule_cached(
        cfg,
        &QnnGraph::sparq_cnn(),
        precision,
        &ctx.cache,
        &ctx.pool,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_ordering_matches_paper_shape() {
        let rows = fig4(false, 42).unwrap();
        assert_eq!(rows.len(), 6);
        let by = |l: &str| rows.iter().find(|r| r.label.starts_with(l)).unwrap().speedup_vs_int16;
        let (int16, w3a3, w1a1, lp, ulp) =
            (by("int16"), by("W3A3"), by("W1A1"), by("LP"), by("ULP"));
        assert!((int16 - 1.0).abs() < 1e-9);
        assert!(w3a3 > 1.0, "W3A3 native must beat int16: {w3a3}");
        assert!(w1a1 > w3a3, "more packing headroom, more speedup");
        assert!(ulp > lp, "ULP (8-bit containers) beats LP");
        assert!(ulp > 2.2, "headline W2A2 speedup too low: {ulp}");
        assert!(lp > 1.4 && lp < 2.2, "W4A4 LP speedup off: {lp}");
    }

    #[test]
    fn fig5_grid_regions() {
        let cells = fig5(true, false, 7).unwrap();
        assert_eq!(cells.len(), 16);
        let at = |w, a| cells.iter().find(|c| c.w_bits == w && c.a_bits == a).unwrap();
        // headline points exist
        assert!(at(2, 2).speedup.unwrap() > 2.0);
        assert!(at(4, 4).speedup.unwrap() > 1.3);
        let native = fig5(false, false, 7).unwrap();
        let nat = |w, a| native.iter().find(|c| c.w_bits == w && c.a_bits == a).unwrap();
        // native cannot do W4A4 at all (paper Fig. 5a region is smaller)
        assert!(nat(4, 4).speedup.is_none());
        assert!(nat(1, 1).speedup.is_some());
        // vmacsr dominates native at every runnable point
        for c in &native {
            if let Some(ns) = c.speedup {
                let vs = at(c.w_bits, c.a_bits).speedup.unwrap();
                assert!(vs > ns * 0.95, "vmacsr not better at W{}A{}", c.w_bits, c.a_bits);
            }
        }
    }

    #[test]
    fn renderers_contain_key_strings() {
        let (ara, sq) = table2();
        let t2 = render_table2(&ara, &sq);
        assert!(t2.contains("Lane Cell Area"));
        assert!(t2.contains("0.120") && t2.contains("0.068"));
        let rows = vec![("fp32".into(), 0.99, 0.0)];
        assert!(render_table1(&rows).contains("fp32"));
    }

    #[test]
    fn warm_fig4_rerun_is_all_hits_and_identical() {
        let ctx = SweepCtx::new();
        let cold = fig4_with(&ctx, false, 42).unwrap();
        let misses = ctx.cache.stats().misses;
        let warm = fig4_with(&ctx, false, 42).unwrap();
        assert_eq!(ctx.cache.stats().misses, misses, "warm rerun recompiled something");
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.cycles, w.cycles, "{}", c.label);
        }
        assert!(ctx.pool.stats().reused > 0);
    }

    #[test]
    fn fig5_grids_share_the_int16_baseline() {
        let ctx = SweepCtx::new();
        fig5_with(&ctx, false, false, 7).unwrap();
        let hits_before = ctx.cache.stats().hits;
        fig5_with(&ctx, true, false, 7).unwrap();
        // the 5b grid reuses 5a's int16 baseline program at minimum
        assert!(ctx.cache.stats().hits > hits_before);
    }

    #[test]
    fn parallel_sweep_matches_the_sequential_path_row_by_row() {
        // the scoped-thread fan-out must be bit-identical to running
        // each (cfg, variant) workload alone through the sequential
        // one-shot path — not merely self-consistent across reruns
        use crate::kernels::run_conv;
        let rows = fig4(false, 11).unwrap();
        let sparq = ProcessorConfig::sparq();
        let ara = ProcessorConfig::ara();
        let plan: Vec<(&ProcessorConfig, ConvVariant)> = vec![
            (&sparq, ConvVariant::Int16),
            (&ara, ConvVariant::Native { w_bits: 3, a_bits: 3 }),
            (&ara, ConvVariant::Native { w_bits: 2, a_bits: 2 }),
            (&ara, ConvVariant::Native { w_bits: 1, a_bits: 1 }),
            (&sparq, ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Paper }),
            (&sparq, ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper }),
        ];
        assert_eq!(rows.len(), plan.len());
        for (row, (cfg, variant)) in rows.iter().zip(plan) {
            let (wb, ab) = variant.bits();
            let wl = Workload::random(ConvDims::fig4(false), wb, ab, 11);
            let seq = run_conv(cfg, &wl, variant).unwrap();
            assert_eq!(row.cycles, seq.report.stats.cycles, "{} diverged", row.label);
        }
    }

    #[test]
    fn warm_qnn_schedule_is_all_hits_and_identical() {
        let ctx = SweepCtx::new();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let cold = qnn_schedule_with(&ctx, &ProcessorConfig::sparq(), prec).unwrap();
        let misses = ctx.cache.stats().misses;
        let warm = qnn_schedule_with(&ctx, &ProcessorConfig::sparq(), prec).unwrap();
        assert_eq!(ctx.cache.stats().misses, misses, "warm qnn schedule recompiled");
        assert_eq!(cold.total_cycles(), warm.total_cycles());
        let rendered = render_schedule(&cold, 1.464);
        assert!(rendered.contains("maxpool2-vec") && rendered.contains("gap+fc-vec"));
        assert!(rendered.contains("weight seed"));
    }

    #[test]
    fn precision_ladder_orders_like_the_paper() {
        let ctx = SweepCtx::new();
        let rows = precision_ladder(&ctx).unwrap();
        assert_eq!(rows.len(), 9);
        let cyc = |label: &str| {
            rows.iter().find(|r| r.label == label).unwrap().schedule.total_cycles()
        };
        // the ladder: fewer bits, fewer cycles (ULP beats LP)
        assert!(cyc("w1a1") <= cyc("w2a2"), "w1a1 must not lose to w2a2");
        assert!(cyc("w2a2") < cyc("w4a4"), "the 3.2x point must beat the 1.7x point");
        // mixed rungs land strictly between their uniform endpoints
        let mixed = cyc("mixed w4a4-stem/w2a2");
        assert!(cyc("w2a2") < mixed && mixed < cyc("w4a4"));
        // the DAG topologies schedule and report real cycle counts
        assert!(cyc("resnetlike w2a2") > 0);
        assert!(cyc("mobilenetlike w2a2") > 0);
        assert!(cyc("denselike w2a2") > 0);
        // a warm rerun is all graph-level hits with zero re-tuning
        let s0 = ctx.cache.stats();
        let again = precision_ladder(&ctx).unwrap();
        let s1 = ctx.cache.stats();
        assert_eq!(s0.misses, s1.misses, "warm ladder recompiled a network");
        assert_eq!(s0.tune_misses, s1.tune_misses, "warm ladder re-tuned a layer");
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.schedule.total_cycles(), b.schedule.total_cycles());
        }
        let rendered = render_ladder(&rows, 1.464);
        assert!(rendered.contains("mixed w4a4-stem/w2a2") && rendered.contains("vmacsr"));
        assert!(rendered.contains("resnetlike w2a2"), "DAG rungs missing from the report");
    }

    #[test]
    fn throughput_sweep_amortizes_monotonically_and_reruns_warm() {
        let ctx = SweepCtx::new();
        let rows = throughput_sweep(&ctx, &[1, 2, 4], 8).unwrap();
        assert_eq!(rows.len(), 3);
        // per-slot cycles are batch-invariant; the preamble is the only
        // amortized term, so img/s at fmax strictly increases with B
        assert!(rows.iter().all(|r| r.slot_cycles == rows[0].slot_cycles));
        assert!(rows.iter().all(|r| r.preamble_cycles == rows[0].preamble_cycles));
        assert!(rows[0].preamble_cycles > 0, "packed network must carry a preamble");
        for pair in rows.windows(2) {
            assert!(
                pair[1].img_per_s_fmax > pair[0].img_per_s_fmax,
                "B={} img/s {} !> B={} img/s {}",
                pair[1].batch,
                pair[1].img_per_s_fmax,
                pair[0].batch,
                pair[0].img_per_s_fmax
            );
        }
        // warm rerun: every batch size is a pure graph-level hit
        let misses = ctx.cache.stats().misses;
        let again = throughput_sweep(&ctx, &[1, 2, 4], 8).unwrap();
        assert_eq!(ctx.cache.stats().misses, misses, "warm sweep recompiled a batch layout");
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.slot_cycles, b.slot_cycles);
            assert_eq!(a.preamble_cycles, b.preamble_cycles);
        }
        let rendered = render_throughput(&rows, 1.464);
        assert!(rendered.contains("preamble") && rendered.contains("img/s@fmax"));
    }

    #[test]
    fn capacity_grid_scales_with_cores_and_matches_the_single_core_sweep() {
        use crate::coordinator::cluster::shard_merge_overhead;
        let ctx = SweepCtx::new();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let rows = capacity_grid(&ctx, &[1, 2, 4], &[4], &[("w2a2", prec)], 8).unwrap();
        assert_eq!(rows.len(), 3);
        // slot and preamble cycles are core-invariant (same compiled
        // model, same per-slot streams — only the assignment changes)
        assert!(rows.iter().all(|r| r.slot_cycles == rows[0].slot_cycles));
        assert!(rows.iter().all(|r| r.preamble_cycles == rows[0].preamble_cycles));
        // the makespan model is closed-form under round-robin full
        // frames: K cores split B=4 slots evenly, the busiest core
        // carries B/K slots plus one preamble, plus the fixed overhead
        let (slot, pre) = (rows[0].slot_cycles, rows[0].preamble_cycles);
        for r in &rows {
            let per_core_slots = 4 / r.cores as u64;
            assert_eq!(r.overhead_cycles, shard_merge_overhead(r.cores));
            assert_eq!(
                r.makespan_cycles,
                pre + per_core_slots * slot + r.overhead_cycles,
                "K={} makespan off the max-over-cores model",
                r.cores
            );
        }
        // img/s strictly increases in cores for fixed batch >= cores
        for pair in rows.windows(2) {
            assert!(
                pair[1].img_per_s_fmax > pair[0].img_per_s_fmax,
                "K={} img/s {} !> K={} img/s {}",
                pair[1].cores,
                pair[1].img_per_s_fmax,
                pair[0].cores,
                pair[0].img_per_s_fmax
            );
        }
        // the K=1 column IS the single-core throughput sweep
        let sweep = throughput_sweep(&ctx, &[4], 8).unwrap();
        assert_eq!(rows[0].slot_cycles, sweep[0].slot_cycles);
        assert_eq!(rows[0].preamble_cycles, sweep[0].preamble_cycles);
        assert_eq!(rows[0].makespan_cycles, pre + 4 * slot, "K=1 pays zero overhead");
        let rendered = render_capacity(&rows, 1.464);
        assert!(rendered.contains("makespan") && rendered.contains("img/s@fmax"));
    }

    #[test]
    fn utilization_in_paper_ballpark() {
        let rows = utilization(false, 3).unwrap();
        for (label, util, _) in &rows {
            assert!(*util > 0.85 && *util <= 1.0, "{label}: {util}");
        }
    }
}
