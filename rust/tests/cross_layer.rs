//! Cross-layer integration: the same packed convolution computed three
//! ways must agree bit-for-bit —
//!
//!   (a) the AOT pallas kernel (python-authored, PJRT-executed in rust),
//!   (b) the rust Sparq simulator running Algorithm 1,
//!   (c) the host golden model.
//!
//! This is the test that proves L1, L3 and the oracle implement the
//! same ULPPACK/vmacsr arithmetic.  Skips (with a message) when
//! `make artifacts` hasn't been run.

use sparq::arch::ProcessorConfig;
use sparq::kernels::workload::golden_exact;
use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
use sparq::runtime::{artifacts_dir, artifacts_present, Runtime, TestSet};
use sparq::ulppack::RegionMode;

/// The standalone kernel artifacts are fixed at (C=16, H=W=18, Co=8,
/// F=3) — see `python/compile/aot.py`.
const C: usize = 16;
const HW: usize = 18;
const CO: usize = 8;
const F: usize = 3;

fn artifact_inputs(wl: &Workload) -> (Vec<i32>, Vec<i32>) {
    let x: Vec<i32> = wl.act.iter().flat_map(|r| r.iter().map(|&v| v as i32)).collect();
    let w: Vec<i32> = wl
        .wgt
        .iter()
        .flat_map(|po| po.iter().flat_map(|f| f.iter().map(|&v| v as i32)))
        .collect();
    (x, w)
}

#[test]
fn pallas_artifact_equals_simulator_equals_oracle() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(artifacts_dir()).expect("runtime");
    let dims =
        ConvDims { c: C as u32, h: HW as u32, w: HW as u32, co: CO as u32, fh: F as u32, fw: F as u32 };

    for (name, w_bits, a_bits) in [("packed_conv2d_lp", 3u32, 3u32), ("packed_conv2d_ulp", 2, 2)] {
        let wl = Workload::random(dims, w_bits, a_bits, 0xC0FFEE);
        let (x, w) = artifact_inputs(&wl);

        // (a) the AOT pallas kernel through PJRT
        let got_pjrt = rt
            .exec_i32(
                name,
                &[
                    (&x, &[C as i64, HW as i64, HW as i64]),
                    (&w, &[CO as i64, C as i64, F as i64, F as i64]),
                ],
            )
            .expect(name);

        // (b) the rust simulator running Algorithm 1 on Sparq
        let run = run_conv(
            &ProcessorConfig::sparq(),
            &wl,
            ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Strict },
        )
        .expect("sim");
        let got_sim = run.out.read_ints(&run.machine.mem).expect("read");

        // (c) the oracle
        let oracle = golden_exact(&wl);

        let pjrt64: Vec<i64> = got_pjrt.iter().map(|&v| v as i64).collect();
        assert_eq!(pjrt64, oracle, "{name}: pallas != oracle");
        assert_eq!(got_sim, oracle, "{name}: simulator != oracle");
    }
}

#[test]
fn qnn_artifacts_all_load_and_predict() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::load(&dir).expect("runtime");
    let ts = TestSet::load(dir.join("testset.bin")).expect("testset");
    assert!(ts.n >= 256);
    for name in ["qnn_fp32", "qnn_w4a4", "qnn_w3a3", "qnn_w2a2"] {
        let art = rt.manifest.artifact(name).expect(name);
        let batch = art.meta_u32("batch").unwrap() as usize;
        let (data, real) = ts.batch(0, batch);
        let logits = rt
            .exec_f32(name, &[(&data, &[batch as i64, 1, 16, 16])])
            .expect(name);
        assert_eq!(logits.len(), batch * 4, "{name}");
        // accuracy of the first batch must beat chance by a wide margin
        let mut correct = 0;
        for i in 0..real {
            let row = &logits[i * 4..(i + 1) * 4];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            correct += (pred == ts.labels[i] as usize) as usize;
        }
        assert!(
            correct as f64 / real as f64 > 0.7,
            "{name}: first-batch accuracy {correct}/{real}"
        );
    }
}

#[test]
fn manifest_metadata_matches_rust_graph() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(artifacts_dir()).expect("runtime");
    // container selection in the manifest must agree with the rust
    // region calculus (paper mapping: W+A<=4 -> ULP, else LP)
    for (name, w, a) in [("qnn_w4a4", 4u32, 4u32), ("qnn_w3a3", 3, 3), ("qnn_w2a2", 2, 2)] {
        let art = rt.manifest.artifact(name).expect(name);
        let container = art.meta_u32("container").unwrap();
        let expected = if w + a <= 4 { 8 } else { 16 };
        assert_eq!(container, expected, "{name}");
        assert_eq!(art.meta_u32("wbits"), Some(w));
        assert_eq!(art.meta_u32("abits"), Some(a));
    }
    // graph shapes agree with the rust-side QnnGraph
    let g = sparq::qnn::QnnGraph::sparq_cnn();
    assert_eq!(g.input, (1, 16, 16));
    let ts_meta = rt.manifest.datum("testset").expect("testset");
    assert_eq!(ts_meta.meta_u32("h"), Some(16));
    assert_eq!(ts_meta.meta_u32("classes"), Some(g.classes));
}
