//! Differential fuzzing of the four execution engines: randomized
//! programs (all `VOp`s x SEW x LMUL x identical/disjoint/partially-
//! overlapping register groups, plus loads/stores/slides/vsetvli
//! churn) run through
//!
//! * `Machine::run_reference`        — the retained per-element oracle,
//! * `Machine::run`                  — the interpreter with its VX fast paths,
//! * `Machine::run_compiled`         — the fused-execution-plan engine,
//! * `Machine::run_compiled_unfused` — the retained per-uop SWAR engine,
//!
//! and every run must agree bit-for-bit on the VRF, the memory, *and*
//! the `RunReport` (cycles, element ops, per-unit busy/inst counters,
//! bytes moved, RAW stalls).  This is the contract that lets the
//! serving stack run the fused plan engine (DESIGN.md §Perf).  The
//! fusion-boundary corpus below additionally hammers the fusion pass
//! itself: contiguous load/store/fill/copy runs with absorbed vsetvli
//! churn, length-1 runs, deliberate contiguity gaps and generic
//! interrupters, unbatched and rebased.

//! Case count: 150 by default (fusion corpus 120); the nightly CI job
//! scales both up via `SPARQ_FUZZ_ITERS` (`testutil::fuzz_iters`) so
//! the deep sweep never taxes PR latency.

use sparq::arch::ProcessorConfig;
use sparq::isa::{Lmul, ScalarKind, Sew, VInst, VOp};
use sparq::sim::{CompiledProgram, Machine, Program, RunReport};
use sparq::testutil::{fuzz_iters, Gen, Prop};

const VLEN: u32 = 512; // small VRF: fast cases, frequent group reuse
const MEM: usize = 1 << 14;

/// A machine with every extension enabled (FPU + vmacsr + cfg-shifter)
/// so the generator can draw from the full op set.
fn fuzz_cfg() -> ProcessorConfig {
    let mut cfg = ProcessorConfig::sparq_cfgshift();
    cfg.fpu = true;
    cfg.vlen_bits = VLEN;
    cfg.name = "fuzz".into();
    cfg
}

struct VState {
    sew: Sew,
    lmul: Lmul,
    vl: u32,
    vlmax: u32,
}

fn pick_sew(g: &mut Gen) -> Sew {
    *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64])
}

fn pick_lmul(g: &mut Gen) -> Lmul {
    *g.pick(&[Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8])
}

/// An LMUL-aligned register whose group fits below v32.
fn reg(g: &mut Gen, factor: u32) -> u8 {
    (g.below((32 / factor) as u64) as u32 * factor) as u8
}

fn setvl(g: &mut Gen, st: &mut VState) -> VInst {
    let sew = pick_sew(g);
    let lmul = pick_lmul(g);
    let vlmax = VLEN / sew.bits() * lmul.factor();
    let avl = g.range(1, (2 * vlmax) as u64);
    st.sew = sew;
    st.lmul = lmul;
    st.vlmax = vlmax;
    st.vl = avl.min(vlmax as u64) as u32;
    VInst::SetVl { avl, sew, lmul }
}

fn arith(g: &mut Gen, st: &VState) -> VInst {
    let f = st.lmul.factor();
    let vd = reg(g, f);
    let vs2 = reg(g, f);
    // the full integer op set; FP only at the modelled SEW=32
    let mut ops = vec![
        VOp::Add,
        VOp::Sub,
        VOp::And,
        VOp::Or,
        VOp::Xor,
        VOp::Sll,
        VOp::Srl,
        VOp::Sra,
        VOp::Min,
        VOp::Max,
        VOp::Mv,
        VOp::Mul,
        VOp::Mulh,
        VOp::Mulhu,
        VOp::Macc,
        VOp::Nmsac,
        VOp::Macsr,
        VOp::MacsrCfg,
    ];
    if st.sew == Sew::E32 {
        ops.extend([VOp::FAdd, VOp::FMul, VOp::FMacc]);
    }
    if st.sew != Sew::E64 {
        ops.push(VOp::WAdduWv);
        ops.push(VOp::NSrl);
    }
    ops.extend([VOp::SlideDown, VOp::SlideUp]);
    let op = *g.pick(&ops);

    if op == VOp::WAdduWv {
        // vd needs a 2*LMUL-aligned group (may partially overlap vs2 —
        // exactly the case the ascending-order engines must get right)
        let df = 2 * f;
        let vd = reg(g, df);
        return VInst::OpVV { op, vd, vs2, vs1: reg(g, f) };
    }
    if op == VOp::NSrl {
        // vs2 is the 2*LMUL wide group; .wx/.wi only (static shift) —
        // vd may overlap the wide source, the ascending order must hold
        let vs2 = reg(g, 2 * f);
        let sh = g.below(2 * st.sew.bits() as u64);
        return if g.bool() && sh < 32 {
            VInst::OpVI { op, vd, vs2, imm: sh as i8 }
        } else {
            VInst::OpVX { op, vd, vs2, rs1: if g.bool() { sh } else { g.next_u64() } }
        };
    }
    if op.is_slide() {
        // .vx/.vi only (no .vv form); vslideup forbids vd == vs2
        let vs2 = if op == VOp::SlideUp {
            let v = reg(g, f);
            if v == vd {
                // next aligned group (still a multiple of f, f | 32)
                ((vd as u32 + f) % 32) as u8
            } else {
                v
            }
        } else {
            vs2
        };
        let off = g.below(st.vlmax as u64 + 2);
        return if g.bool() && off < 32 {
            VInst::OpVI { op, vd, vs2, imm: off as i8 }
        } else {
            VInst::OpVX { op, vd, vs2, rs1: off }
        };
    }
    match g.below(3) {
        0 => VInst::OpVV { op, vd, vs2, vs1: reg(g, f) },
        1 => VInst::OpVX { op, vd, vs2, rs1: g.next_u64() },
        _ => VInst::OpVI { op, vd, vs2, imm: g.irange(-16, 15) as i8 },
    }
}

fn mem_op(g: &mut Gen, st: &VState) -> VInst {
    let f = st.lmul.factor();
    let vlenb = (VLEN / 8) as usize;
    // mixed EEW too (the conv kernels' widened stores do this): pick a
    // base whose vl*EEW-byte access stays inside the register file so
    // all three engines remain legal
    let mut eew = *g.pick(&[Sew::E8, Sew::E16, Sew::E32, Sew::E64]);
    let mut n = st.vl as usize * eew.bytes() as usize;
    let mut fits: Vec<u8> = (0..32 / f)
        .map(|k| (k * f) as u8)
        .filter(|&r| r as usize * vlenb + n <= 32 * vlenb)
        .collect();
    if fits.is_empty() {
        // EEW == SEW always fits every aligned group
        eew = st.sew;
        n = st.vl as usize * eew.bytes() as usize;
        fits = (0..32 / f).map(|k| (k * f) as u8).collect();
    }
    let v = *g.pick(&fits);
    let addr = g.below((MEM - n) as u64 + 1);
    if g.bool() {
        VInst::Load { eew, vd: v, addr }
    } else {
        VInst::Store { eew, vs3: v, addr }
    }
}

fn gen_program(g: &mut Gen) -> (Program, u32) {
    let mut p = Program::new("fuzz");
    let mut st = VState { sew: Sew::E8, lmul: Lmul::M1, vl: 0, vlmax: 0 };
    p.push(setvl(g, &mut st));
    let n = g.range(8, 28);
    for _ in 0..n {
        let inst = match g.below(100) {
            0..=11 => setvl(g, &mut st),
            12..=27 => mem_op(g, &st),
            28..=33 => VInst::Scalar { kind: ScalarKind::LoopCtl, n: g.range(1, 4) as u32 },
            _ => arith(g, &st),
        };
        p.push(inst);
    }
    (p, g.below(16) as u32)
}

fn machine_with_state(cfg: &ProcessorConfig, seed_bytes: &[u8]) -> Machine {
    let mut m = Machine::new(cfg.clone(), MEM);
    let vrf_len = (VLEN / 8 * 32) as usize;
    m.vrf().slice_mut(0, vrf_len).copy_from_slice(&seed_bytes[..vrf_len]);
    m.mem.write(0, &seed_bytes[vrf_len..vrf_len + 4096]).unwrap();
    m
}

fn snapshot(m: &mut Machine) -> (Vec<u8>, Vec<u8>) {
    let vrf_len = (VLEN / 8 * 32) as usize;
    (m.vrf().slice(0, vrf_len).to_vec(), m.mem.read(0, MEM).unwrap().to_vec())
}

fn assert_reports_eq(a: &RunReport, b: &RunReport, what: &str) {
    assert_eq!(a.stats.cycles, b.stats.cycles, "{what}: cycles");
    assert_eq!(a.stats.element_ops, b.stats.element_ops, "{what}: element ops");
    assert_eq!(a.stats.raw_stall_cycles, b.stats.raw_stall_cycles, "{what}: raw stalls");
    assert_eq!(a.stats.bytes_loaded, b.stats.bytes_loaded, "{what}: bytes loaded");
    assert_eq!(a.stats.bytes_stored, b.stats.bytes_stored, "{what}: bytes stored");
    assert_eq!(a.stats.unit_table(), b.stats.unit_table(), "{what}: unit counters");
}

#[test]
fn compiled_and_fast_engines_match_the_reference_bit_for_bit() {
    let cfg = fuzz_cfg();
    Prop::new(0xD1FF).runs(fuzz_iters(150)).check(|g| {
        let (p, csr) = gen_program(g);
        let seed_bytes: Vec<u8> = {
            let n = (VLEN / 8 * 32) as usize + 4096;
            (0..n).map(|_| g.next_u64() as u8).collect()
        };

        let mut m_ref = machine_with_state(&cfg, &seed_bytes);
        let mut m_fast = machine_with_state(&cfg, &seed_bytes);
        let mut m_uop = machine_with_state(&cfg, &seed_bytes);
        let mut m_unf = machine_with_state(&cfg, &seed_bytes);
        m_ref.set_shift_csr(csr);
        m_fast.set_shift_csr(csr);
        m_uop.set_shift_csr(csr);
        m_unf.set_shift_csr(csr);

        let r_ref = m_ref.run_reference(&p).unwrap_or_else(|e| panic!("reference: {e}\n{p:?}"));
        let r_fast = m_fast.run(&p).unwrap_or_else(|e| panic!("interpreter: {e}\n{p:?}"));
        let cp = CompiledProgram::compile(&p, &cfg)
            .unwrap_or_else(|e| panic!("uop compile: {e}\n{p:?}"));
        let r_uop = m_uop.run_compiled(&cp).unwrap_or_else(|e| panic!("uop run: {e}\n{p:?}"));
        let r_unf =
            m_unf.run_compiled_unfused(&cp).unwrap_or_else(|e| panic!("unfused run: {e}\n{p:?}"));

        let s_ref = snapshot(&mut m_ref);
        let s_fast = snapshot(&mut m_fast);
        let s_uop = snapshot(&mut m_uop);
        let s_unf = snapshot(&mut m_unf);
        assert_eq!(s_ref.0, s_fast.0, "interpreter VRF diverged\n{p:?}");
        assert_eq!(s_ref.1, s_fast.1, "interpreter memory diverged\n{p:?}");
        assert_eq!(s_ref.0, s_uop.0, "compiled VRF diverged\n{p:?}");
        assert_eq!(s_ref.1, s_uop.1, "compiled memory diverged\n{p:?}");
        assert_eq!(s_ref.0, s_unf.0, "unfused VRF diverged\n{p:?}");
        assert_eq!(s_ref.1, s_unf.1, "unfused memory diverged\n{p:?}");
        assert_reports_eq(&r_ref, &r_fast, "interpreter");
        assert_reports_eq(&r_ref, &r_uop, "compiled");
        assert_reports_eq(&r_ref, &r_unf, "unfused");
    });
}

// ---------------------------------------------------------- fusion corpus

/// One run-shaped segment for the fusion-boundary corpus: a contiguous
/// load/store run (with scalar slots, re-issued `vsetvli`s and
/// occasional contiguity *gaps* between members), a fill run over
/// ascending registers, or a copy run.  Length-1 "runs" fall out of
/// `members == 1`.
fn fusion_segment(g: &mut Gen, p: &mut Program, st: &mut VState) {
    let vlenb = (VLEN / 8) as usize;
    match g.below(4) {
        0 | 1 => {
            // contiguous memory run at the current vtype
            p.push(setvl(g, st));
            let eew = st.sew;
            let n = st.vl as usize * eew.bytes() as usize;
            let f = st.lmul.factor();
            let regs: Vec<u8> = (0..32 / f).map(|k| (k * f) as u8).collect();
            let members = g.range(1, 6) as usize;
            // keep every member (gaps included) below MEM/2 so the
            // rebased replay at BASE = MEM/2 stays in bounds
            let span = 2 * members * n;
            let addr0 = g.below((MEM / 2 - span) as u64 + 1);
            let store = g.bool();
            let mut addr = addr0;
            for i in 0..members {
                if i > 0 {
                    if g.below(4) == 0 {
                        p.push(VInst::Scalar {
                            kind: ScalarKind::LoopCtl,
                            n: g.range(1, 3) as u32,
                        });
                    }
                    if g.below(5) == 0 {
                        // same-vl vsetvli inside the run: absorbed
                        p.push(VInst::SetVl { avl: st.vl as u64, sew: st.sew, lmul: st.lmul });
                    }
                    if g.below(6) == 0 {
                        addr += n as u64; // gap: the run must split here
                    }
                }
                let r = *g.pick(&regs);
                p.push(if store {
                    VInst::Store { eew, vs3: r, addr }
                } else {
                    VInst::Load { eew, vd: r, addr }
                });
                addr += n as u64;
            }
        }
        2 => {
            // fill run: full-group broadcasts to ascending registers
            let avl = vlenb as u64;
            p.push(VInst::SetVl { avl, sew: Sew::E8, lmul: Lmul::M1 });
            st.sew = Sew::E8;
            st.lmul = Lmul::M1;
            st.vlmax = avl as u32;
            st.vl = avl as u32;
            let b = g.below(27) as u8;
            let k = g.range(1, 5) as u8;
            let imm = g.irange(-4, 7) as i8;
            for i in 0..k {
                // occasional splat mismatch: the run must split there
                let imm = if g.below(8) == 0 { g.irange(-16, 15) as i8 } else { imm };
                p.push(VInst::OpVI { op: VOp::Mv, vd: b + i, vs2: 0, imm });
            }
        }
        _ => {
            // copy run: vmv.v.v over ascending groups, overlap allowed
            let avl = vlenb as u64;
            p.push(VInst::SetVl { avl, sew: Sew::E8, lmul: Lmul::M1 });
            st.sew = Sew::E8;
            st.lmul = Lmul::M1;
            st.vlmax = avl as u32;
            st.vl = avl as u32;
            let k = g.range(1, 5) as u8;
            let d = g.below((32 - k as u64) + 1) as u8;
            let s = g.below((32 - k as u64) + 1) as u8;
            for i in 0..k {
                p.push(VInst::OpVV { op: VOp::Mv, vd: d + i, vs2: 0, vs1: s + i });
            }
        }
    }
    // occasionally a generic op right at the segment edge
    if g.below(3) == 0 {
        let f = st.lmul.factor();
        let r = |g: &mut Gen| (g.below((32 / f) as u64) as u32 * f) as u8;
        p.push(VInst::OpVX { op: VOp::Mulhu, vd: r(g), vs2: r(g), rs1: g.next_u64() });
    }
}

fn gen_run_heavy_program(g: &mut Gen) -> Program {
    let mut p = Program::new("fusion-fuzz");
    let mut st = VState { sew: Sew::E8, lmul: Lmul::M1, vl: 0, vlmax: 0 };
    p.push(setvl(g, &mut st));
    for _ in 0..g.range(3, 7) {
        fusion_segment(g, &mut p, &mut st);
    }
    p
}

/// The fusion-boundary corpus: run-heavy programs executed on all four
/// engines, unbatched and rebased into the upper half of memory, with
/// bit-identical VRF/memory/stats everywhere.  Scaled by
/// `SPARQ_FUZZ_ITERS` like the main fuzz.  The corpus must actually
/// exercise fusion: the aggregate fused-uop count over all cases is
/// asserted nonzero.
#[test]
fn fusion_boundary_corpus_matches_across_engines_and_rebases() {
    let cfg = fuzz_cfg();
    const BASE: u64 = (MEM / 2) as u64; // 64-aligned slot offset
    let mut total_fused = 0u64;
    Prop::new(0xF0_5E).runs(fuzz_iters(120)).check(|g| {
        let p = gen_run_heavy_program(g);
        let seed_bytes: Vec<u8> = {
            let n = (VLEN / 8 * 32) as usize + 4096;
            (0..n).map(|_| g.next_u64() as u8).collect()
        };
        let cp = CompiledProgram::compile(&p, &cfg)
            .unwrap_or_else(|e| panic!("fusion compile: {e}\n{p:?}"));

        let mut m_ref = machine_with_state(&cfg, &seed_bytes);
        let mut m_uop = machine_with_state(&cfg, &seed_bytes);
        let mut m_unf = machine_with_state(&cfg, &seed_bytes);
        let r_ref = m_ref.run_reference(&p).unwrap_or_else(|e| panic!("reference: {e}\n{p:?}"));
        let r_uop = m_uop.run_compiled(&cp).unwrap_or_else(|e| panic!("fused run: {e}\n{p:?}"));
        let r_unf =
            m_unf.run_compiled_unfused(&cp).unwrap_or_else(|e| panic!("unfused: {e}\n{p:?}"));
        assert_eq!(snapshot(&mut m_ref), snapshot(&mut m_uop), "fused diverged\n{p:?}");
        assert_eq!(snapshot(&mut m_ref), snapshot(&mut m_unf), "unfused diverged\n{p:?}");
        assert_reports_eq(&r_ref, &r_uop, "fused");
        assert_reports_eq(&r_ref, &r_unf, "unfused");
        total_fused += r_uop.fused.uops;

        // rebased into the upper half: fused vs unfused engine-to-
        // engine (the interpreter's rebase path is covered elsewhere)
        let mut b_uop = machine_with_state(&cfg, &seed_bytes);
        let mut b_unf = machine_with_state(&cfg, &seed_bytes);
        let rb_uop = b_uop
            .run_compiled_rebased(&cp, BASE)
            .unwrap_or_else(|e| panic!("rebased fused: {e}\n{p:?}"));
        let rb_unf = b_unf
            .run_compiled_unfused_rebased(&cp, BASE)
            .unwrap_or_else(|e| panic!("rebased unfused: {e}\n{p:?}"));
        assert_eq!(snapshot(&mut b_uop), snapshot(&mut b_unf), "rebased diverged\n{p:?}");
        assert_reports_eq(&rb_uop, &rb_unf, "rebased");
        assert_eq!(r_uop.stats.cycles, rb_uop.stats.cycles, "rebase moved cycles\n{p:?}");
    });
    assert!(total_fused > 0, "fusion corpus never produced a fused block");
}

#[test]
fn hot_conv_shapes_match_across_engines() {
    // the exact op mix the conv kernels emit, at the kernels' SEWs —
    // long vectors so the SWAR word loops run many full words + tails
    let cfg = fuzz_cfg();
    for (sew, vl) in [(Sew::E8, 61u64), (Sew::E8, 64), (Sew::E16, 37), (Sew::E16, 32)] {
        let mut p = Program::new("conv-shape");
        p.push(VInst::SetVl { avl: vl, sew, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: sew, vd: 22, addr: 0x40 });
        for k in 0..6u8 {
            p.push(VInst::Scalar { kind: ScalarKind::WeightLoad, n: 1 });
            p.push(VInst::OpVX { op: VOp::Macsr, vd: k, vs2: 22, rs1: 0x9E + k as u64 });
            p.push(VInst::OpVX { op: VOp::Macc, vd: k, vs2: 22, rs1: 3 + k as u64 });
            p.push(VInst::OpVI { op: VOp::SlideDown, vd: 22, vs2: 22, imm: 1 });
        }
        if sew.widened().is_some() {
            p.push(VInst::OpVI { op: VOp::Srl, vd: 23, vs2: 0, imm: 4 });
            p.push(VInst::OpVV { op: VOp::WAdduWv, vd: 8, vs2: 23, vs1: 0 });
            p.push(VInst::OpVI { op: VOp::Mv, vd: 0, vs2: 0, imm: 0 });
        }
        p.push(VInst::Store { eew: sew, vs3: 0, addr: 0x400 });

        let seed_bytes: Vec<u8> = {
            let n = (VLEN / 8 * 32) as usize + 4096;
            (0..n).map(|i| (i as u32).wrapping_mul(2654435761) as u8).collect()
        };
        let mut m_ref = machine_with_state(&cfg, &seed_bytes);
        let mut m_uop = machine_with_state(&cfg, &seed_bytes);
        let r_ref = m_ref.run_reference(&p).unwrap();
        let cp = CompiledProgram::compile(&p, &cfg).unwrap();
        let sc = cp.strategy_counts();
        assert!(sc.swar > 0, "conv shape must land on the SWAR strategy");
        let r_uop = m_uop.run_compiled(&cp).unwrap();
        assert_eq!(snapshot(&mut m_ref), snapshot(&mut m_uop), "{sew:?} vl={vl}");
        assert_reports_eq(&r_ref, &r_uop, "conv shape");
    }
}

#[test]
fn hot_join_and_im2col_shapes_match_across_engines() {
    // the DAG compiler's two new inter-layer streams: the requantizing
    // `vadd.vv` residual join (mixed E32/E16 branch widths, the exact
    // stream `kernels::eltwise` emits) and the im2col strided copy's
    // load/store churn — all three engines must agree bit-for-bit
    use sparq::kernels::asm::Asm;
    use sparq::kernels::eltwise::{emit_add_requant, AddSpec};
    let cfg = fuzz_cfg();
    let mut progs = Vec::new();
    for (a_sew, b_sew, len) in [(Sew::E32, Sew::E16, 96u32), (Sew::E16, Sew::E16, 61)] {
        let mut a = Asm::new("join-shape", cfg.vlen_bits);
        emit_add_requant(
            &mut a,
            &AddSpec {
                a_src: 0x100,
                a_sew,
                a_rshift: 3,
                b_src: 0x900,
                b_sew,
                b_rshift: 1,
                amax: 3,
                dst: 0x1100,
                len,
            },
        );
        progs.push(a.finish(0));
    }
    for sew in [Sew::E8, Sew::E16] {
        // im2col row streaming: unit-stride vle/vse pairs hopping
        // between row-shifted sources and K-major destinations
        let mut p = Program::new("im2col-shape");
        p.push(VInst::SetVl { avl: 48, sew, lmul: Lmul::M2 });
        for r in 0..6u64 {
            p.push(VInst::Load { eew: sew, vd: 0, addr: 0x40 + r * 0x90 });
            p.push(VInst::Store { eew: sew, vs3: 0, addr: 0x1800 + r * 0x60 });
        }
        progs.push(p);
    }
    let seed_bytes: Vec<u8> = {
        let n = (VLEN / 8 * 32) as usize + 4096;
        (0..n).map(|i| (i as u32).wrapping_mul(2246822519) as u8).collect()
    };
    for p in progs {
        let mut m_ref = machine_with_state(&cfg, &seed_bytes);
        let mut m_fast = machine_with_state(&cfg, &seed_bytes);
        let mut m_uop = machine_with_state(&cfg, &seed_bytes);
        let r_ref = m_ref.run_reference(&p).unwrap();
        let r_fast = m_fast.run(&p).unwrap();
        let cp = CompiledProgram::compile(&p, &cfg).unwrap();
        let r_uop = m_uop.run_compiled(&cp).unwrap();
        assert_eq!(snapshot(&mut m_ref), snapshot(&mut m_fast), "{}", p.label);
        assert_eq!(snapshot(&mut m_ref), snapshot(&mut m_uop), "{}", p.label);
        assert_reports_eq(&r_ref, &r_fast, &p.label);
        assert_reports_eq(&r_ref, &r_uop, &p.label);
    }
}

#[test]
fn group_past_v31_is_a_typed_compile_error() {
    // An EEW=64 load under an e8 vtype spans 8x the checked group: the
    // interpreter only catches this via debug_assert/slice panics; the
    // compile path must return the typed SimError instead (satellite:
    // Vrf bounds promotion).
    let cfg = fuzz_cfg();
    let mut p = Program::new("oob");
    p.push(VInst::SetVl { avl: 1 << 16, sew: Sew::E8, lmul: Lmul::M8 });
    p.push(VInst::Load { eew: Sew::E64, vd: 24, addr: 0 });
    assert_eq!(
        CompiledProgram::compile(&p, &cfg).unwrap_err(),
        sparq::sim::SimError::GroupPastV31 { reg: 24, lmul: 8 }
    );
}
