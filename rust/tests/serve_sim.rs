//! End-to-end serving on the simulator backend: the coordinator's
//! workers share one compiled-program cache (Arc), own a machine pool
//! each, and serve real sub-byte conv2d numerics — no PJRT artifacts
//! required, so this path is exercised on every CI run (the PJRT e2e
//! suite skips without `make artifacts`).

use sparq::arch::ProcessorConfig;
use sparq::config::ServeConfig;
use sparq::coordinator::{sim_conv_factory, Server};
use sparq::kernels::workload::golden_exact;
use sparq::kernels::{ConvDims, ConvVariant, ProgramCache, Workload};
use sparq::ulppack::RegionMode;
use std::sync::Arc;

const SEED: u64 = 0x5EED;

fn dims() -> ConvDims {
    ConvDims { c: 4, h: 8, w: 8, co: 2, fh: 3, fw: 3 }
}

fn variant() -> ConvVariant {
    ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict }
}

/// Expected logits for one request image: per-output-channel sums of
/// the exact integer conv with the model's frozen weights.
fn expected_logits(image: &[f32]) -> Vec<f32> {
    let d = dims();
    let mut wl = Workload::random(d, 2, 2, SEED); // same weights the server froze
    let hw = (d.h * d.w) as usize;
    for (c, row) in wl.act.iter_mut().enumerate() {
        for (i, v) in row.iter_mut().enumerate() {
            *v = image[c * hw + i] as u64;
        }
    }
    let out = golden_exact(&wl);
    let plane = (d.ho() * d.wo()) as usize;
    (0..d.co as usize)
        .map(|o| out[o * plane..(o + 1) * plane].iter().sum::<i64>() as f32)
        .collect()
}

#[test]
fn sim_backend_serves_exact_conv_numerics() {
    let cache = Arc::new(ProgramCache::new());
    let server = Server::start(
        sim_conv_factory(
            ProcessorConfig::sparq(),
            dims(),
            variant(),
            4,
            SEED,
            Arc::clone(&cache),
        ),
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 64, ..Default::default() },
        1234,
    )
    .unwrap();

    let image_len = (dims().c * dims().h * dims().w) as usize;
    let n = 12;
    let images: Vec<Vec<f32>> = (0..n)
        .map(|i| (0..image_len).map(|k| ((k + i * 7) % 4) as f32).collect())
        .collect();
    let mut pending = Vec::new();
    for img in &images {
        pending.push(server.submit(img.clone()).expect("submit"));
    }
    for (img, rx) in images.iter().zip(pending) {
        let r = rx.recv().unwrap().expect("infer");
        assert_eq!(r.logits, expected_logits(img), "served numerics diverged from golden");
        assert_eq!(r.sim_cycles, 1234);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, n);
    assert_eq!(snap.errors, 0);

    // the program compiled once; every other worker's lookup was a hit
    let cs = cache.stats();
    assert_eq!(cs.entries, 1, "workers must share one compiled program");
    assert!(cs.hits + cs.misses >= 2, "both workers consulted the shared cache");
}

#[test]
fn sim_backend_batches_and_survives_load() {
    let cache = Arc::new(ProgramCache::new());
    let server = Arc::new(
        Server::start(
            sim_conv_factory(
                ProcessorConfig::sparq(),
                dims(),
                variant(),
                4,
                SEED,
                Arc::clone(&cache),
            ),
            ServeConfig { workers: 2, batch_window_us: 5_000, queue_depth: 128, ..Default::default() },
            0,
        )
        .unwrap(),
    );
    let image_len = (dims().c * dims().h * dims().w) as usize;
    let mut handles = vec![];
    for i in 0..24 {
        let s = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            s.infer(vec![(i % 4) as f32; image_len]).unwrap()
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let max_batch = results.iter().map(|r| r.batch).max().unwrap();
    assert!(max_batch >= 2, "no batching happened under concurrent load");
    let server = Arc::try_unwrap(server).ok().unwrap();
    let snap = server.shutdown();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.errors, 0);
}
