//! Cluster determinism suite (DESIGN.md §Cluster): K-core sharding
//! must be a pure refactor of the 1-core batched path.
//!
//! What it asserts:
//!
//! * **Bit-identity.**  For every fill and every cluster width, the
//!   per-request logits AND per-slot cycles of a K-core frame equal the
//!   1-core goldens exactly — per-slot results are batch-layout-
//!   invariant, so which core runs a slot cannot matter.
//! * **Makespan by construction.**  Every account satisfies
//!   `makespan == max(per_core.cycles) + shard_merge_overhead(fan)`.
//! * **Replay.**  Re-running a round-robin frame reproduces the whole
//!   [`ClusterRun`] (results and account) bit-for-bit.
//! * **Policy agreement.**  Work-steal frames agree with round-robin on
//!   every per-request output (the account is scheduling-dependent and
//!   deliberately not compared).
//! * **Per-core chaos.**  Under a per-core fault plan (a kill + a
//!   recurring error) every request still resolves bounded and typed,
//!   every Ok response bit-matches the clean goldens, and the killed
//!   core stays dead in the health report.
//!
//! `SPARQ_FUZZ_ITERS` scales the sweep (nightly deep-fuzz raises it;
//! the PR matrix runs the defaults).

use std::sync::Arc;
use std::time::Duration;

use sparq::config::ServeConfig;
use sparq::coordinator::cluster::{shard_merge_overhead, QnnCluster, ShardPolicy};
use sparq::coordinator::{
    fault, CallSel, FaultAction, FaultPlan, FaultRule, QnnBatchServer, ServeError,
};
use sparq::kernels::ProgramCache;
use sparq::qnn::schedule::{QnnPrecision, DEFAULT_QNN_SEED};
use sparq::qnn::QnnGraph;
use sparq::runtime::SimQnnModel;
use sparq::{MachinePool, ProcessorConfig};

fn w2a2() -> QnnPrecision {
    QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
}

fn compile(cache: &ProgramCache, batch: u32) -> Arc<SimQnnModel> {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    Arc::new(
        SimQnnModel::compile_batched(&cfg, &graph, w2a2(), DEFAULT_QNN_SEED, cache, batch)
            .expect("batched compile"),
    )
}

fn images(model: &SimQnnModel, n: usize, salt: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..model.input_len())
                .map(|k| ((k as u64).wrapping_mul(salt * 2 + 13) + i as u64).rem_euclid(4) as f32)
                .collect()
        })
        .collect()
}

fn check_account(run: &sparq::coordinator::ClusterRun) {
    let busiest = run.account.per_core.iter().map(|c| c.cycles).max().unwrap_or(0);
    assert_eq!(
        run.account.overhead_cycles,
        shard_merge_overhead(run.account.sharded_across),
        "overhead must follow the fixed fan model"
    );
    assert_eq!(
        run.account.makespan_cycles,
        busiest + run.account.overhead_cycles,
        "makespan must be max-over-cores plus the fixed overhead, by construction"
    );
}

#[test]
fn k_core_frames_are_bit_identical_to_one_core_goldens() {
    let cache = ProgramCache::new();
    let model = compile(&cache, 8);
    let pool = MachinePool::new();
    let iters = sparq::testutil::fuzz_iters(6);
    for it in 0..iters {
        let fill = 1 + (it as usize % 8);
        let imgs = images(&model, fill, it as u64);
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let (golden, _) = model.infer_batch_refs(&pool, &refs).expect("golden batched call");
        for k in [1usize, 2, 3, 4, 8] {
            let cluster = QnnCluster::new(Arc::clone(&model), k, ShardPolicy::RoundRobin);
            let run = cluster.infer_frame(&refs).expect("cluster frame");
            assert_eq!(run.results.len(), fill);
            for (i, g) in golden.iter().enumerate() {
                let r = run.results[i].as_ref().expect("clean cluster slot");
                assert_eq!(
                    r, g,
                    "iter {it} fill {fill} K={k} slot {i}: cluster output must be \
                     bit-identical to the 1-core golden"
                );
            }
            check_account(&run);
            if k == 1 {
                assert_eq!(run.account.overhead_cycles, 0, "K=1 pays zero overhead");
            }
            assert!(run.failed_cores.is_empty());
        }
    }
}

#[test]
fn round_robin_reruns_replay_the_whole_run_bit_for_bit() {
    let cache = ProgramCache::new();
    let model = compile(&cache, 4);
    let imgs = images(&model, 4, 3);
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
    let cluster = QnnCluster::new(Arc::clone(&model), 3, ShardPolicy::RoundRobin);
    let a = cluster.infer_frame(&refs).expect("first run");
    let b = cluster.infer_frame(&refs).expect("second run");
    assert_eq!(a, b, "a round-robin frame must replay bit-for-bit, account included");
    check_account(&a);
    assert_eq!(a.account.sharded_across, 3);
}

#[test]
fn work_steal_agrees_with_round_robin_on_every_output() {
    let cache = ProgramCache::new();
    let model = compile(&cache, 8);
    let iters = sparq::testutil::fuzz_iters(4);
    for it in 0..iters {
        let fill = 1 + (it as usize % 8);
        let imgs = images(&model, fill, 100 + it as u64);
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let rr = QnnCluster::new(Arc::clone(&model), 4, ShardPolicy::RoundRobin);
        let ws = QnnCluster::new(Arc::clone(&model), 4, ShardPolicy::WorkSteal);
        let run_rr = rr.infer_frame(&refs).expect("round-robin frame");
        let run_ws = ws.infer_frame(&refs).expect("work-steal frame");
        for (i, (a, b)) in run_rr.results.iter().zip(&run_ws.results).enumerate() {
            let a = a.as_ref().expect("clean round-robin slot");
            let b = b.as_ref().expect("clean work-steal slot");
            assert_eq!(a, b, "iter {it} slot {i}: policies must agree on every output");
        }
        // the steal account is scheduling-dependent, but it must still
        // satisfy the makespan model over whatever schedule happened
        check_account(&run_ws);
        check_account(&run_rr);
    }
}

#[test]
fn per_core_chaos_keeps_ok_responses_bit_identical_and_kills_stay_dead() {
    // workers: 1, cores: 3.  Core 1 is killed on its first execution;
    // core 2 injects a typed error on every 3rd of its executions.  No
    // CorruptLogits — every Ok response must bit-match the clean
    // goldens.  The serving contract: every request resolves bounded
    // (Ok, or typed failover-exhausted error), the killed core stays
    // dead, the cluster keeps serving on the survivors.
    let cache = ProgramCache::new();
    let core_plan = Arc::new(FaultPlan::from_rules(vec![
        FaultRule { worker: Some(1), when: CallSel::Nth(0), action: FaultAction::Kill },
        FaultRule { worker: Some(2), when: CallSel::Every(3), action: FaultAction::Error },
    ]));
    let serve = ServeConfig {
        workers: 1,
        batch: 4,
        batch_window_us: 200,
        queue_depth: 64,
        cores: 3,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos_cores(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        DEFAULT_QNN_SEED,
        serve,
        &cache,
        None,
        Some(core_plan),
    )
    .unwrap();
    // clean goldens from the same compiled layout, batch-by-batch
    let model = compile(&cache, 4);
    let pool = MachinePool::new();
    let n = sparq::testutil::chaos_iters(24) as usize;
    let imgs = images(&model, n, 7);
    let golden: Vec<(Vec<i64>, u64)> = imgs
        .chunks(4)
        .flat_map(|chunk| {
            let refs: Vec<&[f32]> = chunk.iter().map(|v| v.as_slice()).collect();
            model.infer_batch_refs(&pool, &refs).expect("golden batch").0
        })
        .collect();
    // waves of 16 keep the in-flight count (riders + failover retries)
    // well under the 64-deep ring even when SPARQ_CHAOS_ITERS scales n
    // to thousands in the nightly deep-fuzz job
    let mut oks = 0usize;
    for (w, wave) in imgs.chunks(16).enumerate() {
        let pending: Vec<_> =
            wave.iter().map(|img| server.submit(img.clone()).expect("submit")).collect();
        for (j, rx) in pending.into_iter().enumerate() {
            let i = w * 16 + j;
            // the bounded wait IS the no-hang assertion
            let r = rx
                .recv_timeout(Duration::from_secs(10))
                .unwrap_or_else(|_| panic!("request {i} hung — no reply within 10s"));
            match r {
                Ok(res) => {
                    let want: Vec<f32> = golden[i].0.iter().map(|&v| v as f32).collect();
                    assert_eq!(
                        res.logits, want,
                        "request {i}: Ok logits must bit-match the golden"
                    );
                    assert_eq!(res.sim_cycles, golden[i].1, "request {i}: slot cycles must match");
                    oks += 1;
                }
                Err(ServeError::Worker(msg)) => {
                    assert!(
                        msg.contains("injected error") || fault::is_kill(&msg),
                        "request {i}: only the injected per-core faults may surface: {msg}"
                    );
                }
                other => panic!("request {i}: unexpected outcome {other:?}"),
            }
        }
    }
    assert!(oks > 0, "the surviving cores must keep serving");
    let health = server.health();
    assert_eq!(health.cores.len(), 3);
    assert!(!health.cores[1].alive, "the killed core must stay dead");
    assert_eq!(health.cores_alive, 2);
    assert!(health.cores[1].failures >= 1);
    assert_eq!(health.alive, 1, "the worker itself survives its cores' faults");
    let snap = server.shutdown();
    assert!(snap.core_failures >= 1, "core failures must be counted in the metrics");
}

#[test]
fn a_fully_dead_cluster_answers_kill_typed_instead_of_hanging() {
    // cores: 1 and the only core is killed on its first execution: the
    // whole cluster is dead, the rider fails over once and is answered
    // typed by the terminal drain — never a hang.  Later submits fail
    // fast once the worker notices.
    let cache = ProgramCache::new();
    let core_plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::Always,
        action: FaultAction::Kill,
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 1,
        batch_window_us: 50,
        queue_depth: 16,
        cores: 1,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos_cores(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        DEFAULT_QNN_SEED,
        serve,
        &cache,
        None,
        Some(core_plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    let rx = server.submit(image.clone()).expect("submit");
    // the first execution kills the core; the rider fails over into
    // the now-dead cluster and the exiting worker's terminal drain
    // answers it as a dead-pool refusal (or, if the retry raced the
    // exit, as the kill sentinel) — either way typed and bounded
    match rx.recv_timeout(Duration::from_secs(10)).expect("request hung") {
        Err(ServeError::NoWorkers) => {}
        Err(ServeError::Worker(msg)) => assert!(fault::is_kill(&msg), "{msg}"),
        other => panic!("a dead cluster must answer typed, got {other:?}"),
    }
    assert_eq!(server.health().cores_alive, 0);
    server.shutdown();
}
