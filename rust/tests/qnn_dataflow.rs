//! Cross-layer proof of the end-to-end dataflow executor
//! (acceptance criteria of the multi-layer refactor and the
//! mixed-precision compilation on top of it):
//!
//! 1. every graph-layer boundary of an executed inference matches the
//!    host golden network bit-for-bit — uniform AND mixed per-layer
//!    precisions,
//! 2. `Server::infer` returns the golden argmax for a batch of test
//!    images,
//! 3. a second inference through the shared `ProgramCache` is all
//!    hits with identical cycle counts (mixed networks included, with
//!    zero re-tuning),
//! 4. illegal mixed graphs are rejected with the typed
//!    `GraphError`/`SimError` — mismatched boundary widths,
//!    vmacsr-only precisions on an Ara config, W/A outside 1..=4,
//! 5. DAG topologies (residual `Add` joins, depthwise + pointwise
//!    convs, `Dense` im2col-GEMM heads) pin bit-for-bit at every node
//!    boundary, serve batched, and reject malformed DAGs (cycles,
//!    wrong join fan-in, mixed-domain joins) with typed errors.

use sparq::arch::ProcessorConfig;
use sparq::config::ServeConfig;
use sparq::coordinator::{sim_qnn_factory, Server};
use sparq::kernels::ProgramCache;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::{CompiledQnn, GraphError, LayerDesc, QnnGraph, QnnNet};
use sparq::sim::{Machine, MachinePool, SimError};
use std::sync::Arc;

const SEED: u64 = 0x0DD_5EED;

fn precisions() -> [QnnPrecision; 3] {
    [
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        QnnPrecision::SubByte { w_bits: 3, a_bits: 3 },
        QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
    ]
}

#[test]
fn every_layer_boundary_matches_the_golden_network() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    for prec in precisions() {
        let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
        let cq = CompiledQnn::compile(&cfg, net).unwrap();
        for image_seed in [1u64, 42, 0xFFFF_FFFF] {
            let image = cq.net.test_image(image_seed);
            let golden = cq.net.golden_forward(&image).unwrap();
            let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
            let run = cq.execute(&mut m, &image).unwrap();
            for li in 0..graph.layers.len() {
                assert_eq!(
                    cq.read_tap(&m, li).unwrap(),
                    golden.layer_outs[li],
                    "{} image {image_seed}: layer {li} ({}) diverged",
                    prec.label(),
                    graph.layers[li].name()
                );
            }
            assert_eq!(run.logits, golden.logits, "{} logits", prec.label());
            assert_eq!(run.argmax, golden.argmax, "{} argmax", prec.label());
        }
    }
}

#[test]
fn server_infer_returns_the_golden_argmax_for_a_batch() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let cache = Arc::new(ProgramCache::new());
    // pre-warm: compile the network once before the workers start, so
    // both worker lookups are deterministic hits (without this the two
    // workers race factory() and may both miss-compile concurrently)
    cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    let server = Server::start(
        sim_qnn_factory(cfg.clone(), graph.clone(), prec, 4, SEED, Arc::clone(&cache)),
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 64, ..Default::default() },
        1234,
    )
    .unwrap();

    let n = 12;
    let images: Vec<Vec<u64>> = (0..n).map(|i| net.test_image(100 + i as u64)).collect();
    let mut pending = Vec::new();
    for img in &images {
        let fimg: Vec<f32> = img.iter().map(|&v| v as f32).collect();
        pending.push(server.submit(fimg).expect("submit"));
    }
    for (img, rx) in images.iter().zip(pending) {
        let golden = net.golden_forward(img).unwrap();
        let r = rx.recv().unwrap().expect("infer");
        assert_eq!(r.class, golden.argmax, "served classification diverged from golden");
        let glogits: Vec<f32> = golden.logits.iter().map(|&v| v as f32).collect();
        assert_eq!(r.logits, glogits, "served logits diverged from golden");
        assert_eq!(r.sim_cycles, 1234);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, n);
    assert_eq!(snap.errors, 0);

    // the network compiled exactly once (the pre-warm); both workers'
    // lookups hit the shared entry
    let cs = cache.stats();
    assert_eq!(cs.entries, 1, "workers must share one compiled network");
    assert_eq!(cs.misses, 1, "nothing may recompile after the pre-warm");
    assert!(cs.hits >= 2, "both workers' lookups must hit");
}

#[test]
fn second_inference_through_the_shared_cache_is_all_hits_with_identical_cycles() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let cache = ProgramCache::new();
    let pool = MachinePool::new();

    let cq = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    let misses_after_compile = cache.stats().misses;
    let image = cq.net.test_image(9);

    let mut m = pool.acquire(&cfg, cq.mem_bytes);
    let first = cq.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);

    // second inference: the cache lookup must hit, nothing recompiles
    let cq2 = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    assert!(Arc::ptr_eq(&cq, &cq2), "second lookup must return the same compiled network");
    assert_eq!(cache.stats().misses, misses_after_compile, "second inference recompiled");
    assert!(cache.stats().hits >= 1);

    let mut m = pool.acquire(&cfg, cq2.mem_bytes);
    let second = cq2.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);

    assert_eq!(first.logits, second.logits);
    assert_eq!(first.total_cycles(), second.total_cycles());
    // stage-by-stage identical, not just in aggregate
    let a: Vec<u64> = first.stage_reports.iter().map(|r| r.stats.cycles).collect();
    let b: Vec<u64> = second.stage_reports.iter().map(|r| r.stats.cycles).collect();
    assert_eq!(a, b);
    assert_eq!(pool.stats().reused, 1, "the machine pool must recycle the arena machine");
}

#[test]
fn mixed_precision_network_is_pinned_at_every_boundary_and_all_hits_on_repeat() {
    // the acceptance configuration: W4A4 stem-adjacent conv, W2A2
    // deeper conv, network default W2A2
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let cache = ProgramCache::new();
    let pool = MachinePool::new();

    let cq = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    // on Sparq the autotuned winners are the canonical vmacsr
    // assignment, so the extended golden_forward (canonical variants)
    // pins the autotuned execution directly
    assert_eq!(cq.variants, cq.net.canonical_variants());
    for image_seed in [2u64, 77, 0xDEAD_BEEF] {
        let image = cq.net.test_image(image_seed);
        let golden = cq.net.golden_forward(&image).unwrap();
        assert_eq!(golden.layer_outs.len(), graph.layers.len());
        let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        for li in 0..graph.layers.len() {
            assert_eq!(
                cq.read_tap(&m, li).unwrap(),
                golden.layer_outs[li],
                "mixed image {image_seed}: layer {li} ({}) diverged",
                graph.layers[li].name()
            );
        }
        assert_eq!(run.logits, golden.logits);
        assert_eq!(run.argmax, golden.argmax);
    }

    // the W4A4 stem-adjacent layer really runs at W4A4 weights: its
    // level range exceeds anything a W2 layer could hold
    let wmax = cq.net.conv_wgt[1].iter().flatten().flatten().copied().max().unwrap();
    assert!(wmax > 2 && wmax <= 14, "override weights out of the W4 range: {wmax}");
    // ...while its uniform twin stays in the W2 range
    let uniform = cache.get_or_compile_qnn(&cfg, &QnnGraph::sparq_cnn(), prec, SEED).unwrap();
    let umax = uniform.net.conv_wgt[1].iter().flatten().flatten().copied().max().unwrap();
    assert!(umax <= 2, "uniform W2 weights out of range: {umax}");

    // repeat inference: pure graph-level hit, zero re-tuning,
    // identical per-stage cycles
    let stats_before = cache.stats();
    let cq2 = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    assert!(Arc::ptr_eq(&cq, &cq2));
    let stats_after = cache.stats();
    assert_eq!(stats_after.misses, stats_before.misses);
    assert_eq!(stats_after.tune_misses, stats_before.tune_misses, "repeat lookup re-tuned");
    let image = cq.net.test_image(5);
    let mut m = pool.acquire(&cfg, cq.mem_bytes);
    let a = cq.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);
    let mut m = pool.acquire(&cfg, cq.mem_bytes);
    let b = cq2.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);
    let ac: Vec<u64> = a.stage_reports.iter().map(|r| r.stats.cycles).collect();
    let bc: Vec<u64> = b.stage_reports.iter().map(|r| r.stats.cycles).collect();
    assert_eq!(ac, bc, "per-stage cycles must be identical across repeat inference");
    assert_eq!(a.logits, b.logits);
}

#[test]
fn mixed_boundary_width_mismatch_rejected_with_typed_error() {
    // W4A4 producer with 162 packed issues: the LP plan spills to the
    // wide u32 accumulator; its W2A2 consumer loads 8-bit ULP
    // containers — a 32 -> 8 boundary is two vnsrl steps
    let graph = QnnGraph::chain(
        vec![
            LayerDesc::Conv {
                c_in: 36,
                c_out: 8,
                h: 8,
                w: 8,
                f: 3,
                quantized: true,
                precision: Some((4, 4)),
            },
            LayerDesc::Conv {
                c_in: 8,
                c_out: 4,
                h: 8,
                w: 8,
                f: 3,
                quantized: true,
                precision: Some((2, 2)),
            },
            LayerDesc::GapFc { c: 4, classes: 4 },
        ],
        (36, 8, 8),
        4,
    );
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    // the typed GraphError from the validator...
    assert_eq!(
        graph.validate_for(&ProcessorConfig::sparq(), prec),
        Err(GraphError::BoundaryWidth { layer: 1, from_bits: 32, to_bits: 8 })
    );
    // ...and the compiler surfaces it as SimError::Graph
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let r = CompiledQnn::compile(&ProcessorConfig::sparq(), net);
    match r {
        Err(SimError::Graph(msg)) => assert!(msg.contains("narrows"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
}

#[test]
fn vmacsr_only_precision_on_ara_rejected_with_typed_error() {
    // W4A4 needs vmacsr (no native plan): an Ara-like config without
    // the instruction must refuse at validation, not at execution
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 4, a_bits: 4 };
    assert!(matches!(
        graph.validate_for(&ProcessorConfig::ara(), prec),
        Err(GraphError::VariantUnsupported { layer: 1, w_bits: 4, a_bits: 4, .. })
    ));
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    match CompiledQnn::compile(&ProcessorConfig::ara(), net) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("vmacsr"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
    // a mixed override to a vmacsr-only precision is rejected the same
    let mixed = QnnGraph::sparq_cnn_mixed((2, 2), (4, 4));
    let base = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    assert!(matches!(
        mixed.validate_for(&ProcessorConfig::ara(), base),
        Err(GraphError::VariantUnsupported { layer: 3, .. })
    ));
}

#[test]
fn precision_outside_one_to_four_rejected_with_typed_error() {
    // an explicit override out of range fails graph validation
    let g = QnnGraph::sparq_cnn_mixed((5, 5), (2, 2));
    assert_eq!(
        g.validate(),
        Err(GraphError::BadPrecision { layer: 1, w_bits: 5, a_bits: 5 })
    );
    match QnnNet::from_seed(&g, QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }, SEED) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("1..=4"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
    // and so does an out-of-range network default (resolved per layer)
    let g = QnnGraph::sparq_cnn();
    match QnnNet::from_seed(&g, QnnPrecision::SubByte { w_bits: 2, a_bits: 9 }, SEED) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("1..=4"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
}

#[test]
fn whole_network_serves_on_ara_via_native_kernels() {
    // scenario diversity: without vmacsr the autotuner falls back to
    // the native ULPPACK scheme, and the whole dataflow network still
    // executes and pins bit-for-bit under the chosen variants
    let cfg = ProcessorConfig::ara();
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let cq = CompiledQnn::compile(&cfg, net).unwrap();
    // the quantized layers picked a native variant (no vmacsr on Ara)
    let labels: Vec<String> = cq.variants.iter().map(|v| v.label()).collect();
    assert!(labels[1].contains("W2A2") && !labels[1].contains("vmacsr"), "{labels:?}");
    let image = cq.net.test_image(4);
    let golden = cq.golden(&image).unwrap();
    let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
    let run = cq.execute(&mut m, &image).unwrap();
    for li in 0..graph.layers.len() {
        assert_eq!(cq.read_tap(&m, li).unwrap(), golden.layer_outs[li], "ara layer {li}");
    }
    assert_eq!(run.logits, golden.logits);
}

#[test]
fn dag_topologies_pin_every_node_boundary_at_uniform_precisions() {
    // residual, depthwise+pointwise and dense-head topologies, each at
    // the ULP (W2A2) and LP (W4A4) uniform precisions: every node
    // boundary of an executed inference equals the golden DAG walk
    let cfg = ProcessorConfig::sparq();
    let graphs = [
        ("resnetlike", QnnGraph::sparq_resnetlike()),
        ("mobilenetlike", QnnGraph::sparq_mobilenetlike()),
        ("denselike", QnnGraph::sparq_denselike()),
    ];
    for (name, graph) in &graphs {
        for prec in [
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
        ] {
            let net = QnnNet::from_seed(graph, prec, SEED).unwrap();
            let cq = CompiledQnn::compile(&cfg, net).unwrap();
            for image_seed in [3u64, 0xBEEF] {
                let image = cq.net.test_image(image_seed);
                let golden = cq.golden(&image).unwrap();
                let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
                let run = cq.execute(&mut m, &image).unwrap();
                for li in 0..graph.layers.len() {
                    assert_eq!(
                        cq.read_tap(&m, li).unwrap(),
                        golden.layer_outs[li],
                        "{name} {} image {image_seed}: layer {li} ({}) diverged",
                        prec.label(),
                        graph.layers[li].name()
                    );
                }
                assert_eq!(run.logits, golden.logits, "{name} {} logits", prec.label());
                assert_eq!(run.argmax, golden.argmax, "{name} {} argmax", prec.label());
            }
        }
    }
}

#[test]
fn dag_topologies_serve_batched_with_bit_identical_slots() {
    // a batched compilation of each DAG topology: every slot of a full
    // batch pins against the golden network, and the whole network is
    // a single cache entry on repeat lookups
    let cfg = ProcessorConfig::sparq();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let cache = ProgramCache::new();
    for graph in [
        QnnGraph::sparq_resnetlike(),
        QnnGraph::sparq_mobilenetlike(),
        QnnGraph::sparq_denselike(),
    ] {
        let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
        let cq = CompiledQnn::compile_batched(&cfg, net, &cache, 3).unwrap();
        let images: Vec<Vec<u64>> = (0..3).map(|i| cq.net.test_image(50 + i)).collect();
        let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
        let batch = cq.execute_batch(&mut m, &images).unwrap();
        assert_eq!(batch.runs.len(), 3);
        for (slot, img) in images.iter().enumerate() {
            let golden = cq.golden(img).unwrap();
            assert_eq!(batch.runs[slot].logits, golden.logits, "slot {slot} logits");
            for li in 0..graph.layers.len() {
                assert_eq!(
                    cq.read_tap_slot(&m, li, slot as u32).unwrap(),
                    golden.layer_outs[li],
                    "slot {slot} layer {li} ({})",
                    graph.layers[li].name()
                );
            }
        }
        // packed networks hoist their weight-pack pass per batch
        assert!(batch.preamble_cycles() > 0, "packed DAG must hoist a preamble");
    }
}

#[test]
fn dag_server_infers_the_residual_network_end_to_end() {
    // the serving stack is topology-agnostic: a residual DAG serves
    // through the same worker/cache path as the chain
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_resnetlike();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let cache = Arc::new(ProgramCache::new());
    cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    let server = Server::start(
        sim_qnn_factory(cfg, graph, prec, 4, SEED, Arc::clone(&cache)),
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 16, ..Default::default() },
        99,
    )
    .unwrap();
    let images: Vec<Vec<u64>> = (0..6).map(|i| net.test_image(7 + i)).collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| {
            let fimg: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            server.submit(fimg).expect("submit")
        })
        .collect();
    for (img, rx) in images.iter().zip(pending) {
        let golden = net.golden_forward(img).unwrap();
        let r = rx.recv().unwrap().expect("infer");
        assert_eq!(r.class, golden.argmax, "served residual classification diverged");
    }
    let snap = server.shutdown();
    assert_eq!(snap.errors, 0);
    assert_eq!(cache.stats().entries, 1, "one compiled network for all workers");
}

#[test]
fn malformed_dags_are_rejected_with_typed_errors_end_to_end() {
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    // a self-loop: no topological order exists
    let mut g = QnnGraph::sparq_cnn();
    g.preds[2] = vec![2];
    assert_eq!(g.validate(), Err(GraphError::Cycle { layer: 2 }));
    match QnnNet::from_seed(&g, prec, SEED) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("cycle"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
    // an Add with one input edge: wrong fan-in
    let mut g = QnnGraph::sparq_resnetlike();
    g.preds[3] = vec![2];
    assert!(matches!(
        g.validate(),
        Err(GraphError::FanInMismatch { layer: 3, expected: 2, got: 1 })
    ));
    match QnnNet::from_seed(&g, prec, SEED) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("input edge"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
    // a residual join whose branches resolve to different activation
    // domains: W4A4 on one branch, the W2A2 default on the other.
    // Resolution happens against the processor, so this surfaces at
    // compile (validate_for), not at weight drawing.
    let mut g = QnnGraph::sparq_resnetlike();
    if let LayerDesc::Conv { precision, .. } = &mut g.layers[2] {
        *precision = Some((4, 4));
    } else {
        panic!("resnetlike layer 2 must be the body conv");
    }
    assert!(matches!(
        g.validate_for(&ProcessorConfig::sparq(), prec),
        Err(GraphError::JoinPrecision { layer: 3, .. })
    ));
    let net = QnnNet::from_seed(&g, prec, SEED).unwrap();
    match CompiledQnn::compile(&ProcessorConfig::sparq(), net) {
        Err(SimError::Graph(msg)) => assert!(msg.contains("join"), "{msg}"),
        other => panic!("expected SimError::Graph, got {other:?}"),
    }
}

#[test]
fn distinct_images_produce_distinct_logits() {
    // sanity against a degenerate pipeline (e.g. a requant shift that
    // flattens everything to zero): different images must reach the
    // head as different activations
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let logit_sets: std::collections::HashSet<Vec<i64>> = (0..16)
        .map(|i| net.golden_forward(&net.test_image(i)).unwrap().logits)
        .collect();
    assert!(logit_sets.len() > 1, "every image produced identical logits");
    assert!(
        logit_sets.iter().any(|l| l.iter().any(|&v| v > 0)),
        "the network flattened every activation to zero"
    );
}
