//! Cross-layer proof of the end-to-end dataflow executor
//! (acceptance criteria of the multi-layer refactor):
//!
//! 1. every graph-layer boundary of an executed inference matches the
//!    host golden network bit-for-bit,
//! 2. `Server::infer` returns the golden argmax for a batch of test
//!    images,
//! 3. a second inference through the shared `ProgramCache` is all
//!    hits with identical cycle counts.

use sparq::arch::ProcessorConfig;
use sparq::config::ServeConfig;
use sparq::coordinator::{sim_qnn_factory, Server};
use sparq::kernels::ProgramCache;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::{CompiledQnn, QnnGraph, QnnNet};
use sparq::sim::{Machine, MachinePool};
use std::sync::Arc;

const SEED: u64 = 0x0DD_5EED;

fn precisions() -> [QnnPrecision; 3] {
    [
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        QnnPrecision::SubByte { w_bits: 3, a_bits: 3 },
        QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
    ]
}

#[test]
fn every_layer_boundary_matches_the_golden_network() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    for prec in precisions() {
        let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
        let cq = CompiledQnn::compile(&cfg, net).unwrap();
        for image_seed in [1u64, 42, 0xFFFF_FFFF] {
            let image = cq.net.test_image(image_seed);
            let golden = cq.net.golden_forward(&image).unwrap();
            let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
            let run = cq.execute(&mut m, &image).unwrap();
            for li in 0..graph.layers.len() {
                assert_eq!(
                    cq.read_tap(&m, li).unwrap(),
                    golden.layer_outs[li],
                    "{} image {image_seed}: layer {li} ({}) diverged",
                    prec.label(),
                    graph.layers[li].name()
                );
            }
            assert_eq!(run.logits, golden.logits, "{} logits", prec.label());
            assert_eq!(run.argmax, golden.argmax, "{} argmax", prec.label());
        }
    }
}

#[test]
fn server_infer_returns_the_golden_argmax_for_a_batch() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let cache = Arc::new(ProgramCache::new());
    // pre-warm: compile the network once before the workers start, so
    // both worker lookups are deterministic hits (without this the two
    // workers race factory() and may both miss-compile concurrently)
    cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    let server = Server::start(
        sim_qnn_factory(cfg.clone(), graph.clone(), prec, 4, SEED, Arc::clone(&cache)),
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 64 },
        1234,
    )
    .unwrap();

    let n = 12;
    let images: Vec<Vec<u64>> = (0..n).map(|i| net.test_image(100 + i as u64)).collect();
    let mut pending = Vec::new();
    for img in &images {
        let fimg: Vec<f32> = img.iter().map(|&v| v as f32).collect();
        pending.push(server.submit(fimg).expect("submit"));
    }
    for (img, rx) in images.iter().zip(pending) {
        let golden = net.golden_forward(img).unwrap();
        let r = rx.recv().unwrap().expect("infer");
        assert_eq!(r.class, golden.argmax, "served classification diverged from golden");
        let glogits: Vec<f32> = golden.logits.iter().map(|&v| v as f32).collect();
        assert_eq!(r.logits, glogits, "served logits diverged from golden");
        assert_eq!(r.sim_cycles, 1234);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, n);
    assert_eq!(snap.errors, 0);

    // the network compiled exactly once (the pre-warm); both workers'
    // lookups hit the shared entry
    let cs = cache.stats();
    assert_eq!(cs.entries, 1, "workers must share one compiled network");
    assert_eq!(cs.misses, 1, "nothing may recompile after the pre-warm");
    assert!(cs.hits >= 2, "both workers' lookups must hit");
}

#[test]
fn second_inference_through_the_shared_cache_is_all_hits_with_identical_cycles() {
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let cache = ProgramCache::new();
    let pool = MachinePool::new();

    let cq = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    let misses_after_compile = cache.stats().misses;
    let image = cq.net.test_image(9);

    let mut m = pool.acquire(&cfg, cq.mem_bytes);
    let first = cq.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);

    // second inference: the cache lookup must hit, nothing recompiles
    let cq2 = cache.get_or_compile_qnn(&cfg, &graph, prec, SEED).unwrap();
    assert!(Arc::ptr_eq(&cq, &cq2), "second lookup must return the same compiled network");
    assert_eq!(cache.stats().misses, misses_after_compile, "second inference recompiled");
    assert!(cache.stats().hits >= 1);

    let mut m = pool.acquire(&cfg, cq2.mem_bytes);
    let second = cq2.execute_fresh(&mut m, &image).unwrap();
    pool.release(m);

    assert_eq!(first.logits, second.logits);
    assert_eq!(first.total_cycles(), second.total_cycles());
    // stage-by-stage identical, not just in aggregate
    let a: Vec<u64> = first.stage_reports.iter().map(|r| r.stats.cycles).collect();
    let b: Vec<u64> = second.stage_reports.iter().map(|r| r.stats.cycles).collect();
    assert_eq!(a, b);
    assert_eq!(pool.stats().reused, 1, "the machine pool must recycle the arena machine");
}

#[test]
fn distinct_images_produce_distinct_logits() {
    // sanity against a degenerate pipeline (e.g. a requant shift that
    // flattens everything to zero): different images must reach the
    // head as different activations
    let graph = QnnGraph::sparq_cnn();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let net = QnnNet::from_seed(&graph, prec, SEED).unwrap();
    let logit_sets: std::collections::HashSet<Vec<i64>> = (0..16)
        .map(|i| net.golden_forward(&net.test_image(i)).unwrap().logits)
        .collect();
    assert!(logit_sets.len() > 1, "every image produced identical logits");
    assert!(
        logit_sets.iter().any(|l| l.iter().any(|&v| v > 0)),
        "the network flattened every activation to zero"
    );
}
