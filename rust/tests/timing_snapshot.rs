//! Golden-timing snapshot: cycle counts for the canonical workload set
//! — conv 3x3 at each sub-byte precision x each variant, the int16
//! baseline, a requant boundary, a 2x2 maxpool, and the GAP+FC head —
//! pinned against `rust/tests/data/timing_snapshot.txt`.  Any
//! timing-model drift now fails THIS test loudly instead of silently
//! skewing autotune decisions and bench JSONs.
//!
//! ## Update protocol
//!
//! The snapshot is a text file of `<key> <cycles>` lines.  To re-bless
//! after an *intentional* timing-model change:
//!
//! ```text
//! SPARQ_BLESS=1 cargo test --test timing_snapshot
//! git add rust/tests/data/timing_snapshot.txt   # commit with the change
//! ```
//!
//! Bootstrap: a file whose first line is `# UNBLESSED` (the committed
//! placeholder in environments with no Rust toolchain to generate real
//! literals) is rewritten in place with the measured values and the
//! test passes with a loud notice; from then on — including the very
//! next test invocation in the same checkout, which is why CI runs
//! this test both inside tier-1 and as an explicit gate step — the
//! comparison is strict.  Determinism is always enforced: the whole
//! set is measured twice and must agree bit-for-bit before any
//! comparison or bless happens.

use sparq::arch::ProcessorConfig;
use sparq::isa::Sew;
use sparq::kernels::asm::Asm;
use sparq::kernels::pool_fc::{emit_gap_fc, emit_maxpool2};
use sparq::kernels::requant::{emit_requant, RequantSpec};
use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
use sparq::sim::Machine;
use sparq::ulppack::{region, RegionMode};
use std::fmt::Write as _;
use std::path::PathBuf;

fn snapshot_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/timing_snapshot.txt")
}

/// Run a standalone emitted stream on a fresh Sparq machine; cycles.
fn run_stream(build: impl FnOnce(&mut Asm)) -> u64 {
    let cfg = ProcessorConfig::sparq();
    let mut m = Machine::new(cfg.clone(), 1 << 20);
    let mut a = Asm::new("snapshot", cfg.vlen_bits);
    build(&mut a);
    m.run(&a.finish(0)).expect("snapshot stream must be legal").stats.cycles
}

/// The canonical workload set, measured.  Deterministic order and
/// deterministic cycles (the timing model is data-independent, and the
/// tensors are seeded).
fn measure() -> Vec<(String, u64)> {
    let cfg = ProcessorConfig::sparq();
    let dims = ConvDims { c: 8, h: 10, w: 18, co: 2, fh: 3, fw: 3 };
    let mut rows = Vec::new();

    // conv 3x3: the int16 baseline + every diagonal precision on both
    // packed variants (where the region calculus admits them)
    let wl16 = Workload::random(dims, 8, 8, 0x7171);
    let r = run_conv(&cfg, &wl16, ConvVariant::Int16).expect("int16 conv");
    rows.push(("conv3x3-int16".to_string(), r.report.stats.cycles));
    for b in 1..=4u32 {
        let wl = Workload::random(dims, b, b, 0x7171 + b as u64);
        let vm = ConvVariant::Vmacsr { w_bits: b, a_bits: b, mode: RegionMode::Paper };
        let r = run_conv(&cfg, &wl, vm).expect("vmacsr conv");
        rows.push((format!("conv3x3-w{b}a{b}-vmacsr"), r.report.stats.cycles));
        if region::plan_native(b, b).is_some() {
            let r = run_conv(&cfg, &wl, ConvVariant::Native { w_bits: b, a_bits: b })
                .expect("native conv");
            rows.push((format!("conv3x3-w{b}a{b}-native"), r.report.stats.cycles));
        }
    }

    // a layer boundary: E32 sums -> E16 levels, 1-wide border, one
    // padding channel (the shape the dataflow compiler emits)
    let spec = RequantSpec {
        src: 0x1000,
        src_sew: Sew::E32,
        c: 3,
        h: 5,
        w: 7,
        dst: 0x8000,
        dst_sew: Sew::E16,
        c_pad: 4,
        pad: 1,
        rshift: 6,
        amax: 15,
    };
    rows.push(("requant-e32-e16-pad1".to_string(), run_stream(|a| emit_requant(a, &spec))));

    // 2x2 maxpool over 3x6x8 at E16
    rows.push((
        "maxpool2-3x6x8-e16".to_string(),
        run_stream(|a| emit_maxpool2(a, 3, 6, 8, Sew::E16, 0x1000, 0x8000)),
    ));

    // GAP+FC head: 32 channels x 16 elements, 4 classes, E16 levels
    let fc_wgt: Vec<Vec<u64>> =
        (0..4u64).map(|k| (0..32u64).map(|c| (k * 7 + c) % 15).collect()).collect();
    rows.push((
        "gapfc-32x16-e16".to_string(),
        run_stream(|a| emit_gap_fc(a, 32, 16, Sew::E16, 0x1000, &fc_wgt, 0xC000)),
    ));

    rows
}

fn render(rows: &[(String, u64)]) -> String {
    let mut s = String::from(
        "# Golden timing snapshot (cycles) — see rust/tests/timing_snapshot.rs\n\
         # for the update protocol (SPARQ_BLESS=1 cargo test --test timing_snapshot).\n",
    );
    for (k, v) in rows {
        let _ = writeln!(s, "{k} {v}");
    }
    s
}

fn parse(text: &str) -> Vec<(String, u64)> {
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| {
            let mut it = l.split_whitespace();
            let k = it.next().expect("snapshot line key").to_string();
            let v = it.next().expect("snapshot line cycles").parse().expect("snapshot cycles u64");
            (k, v)
        })
        .collect()
}

#[test]
fn timing_snapshot_is_pinned() {
    let first = measure();
    let second = measure();
    assert_eq!(first, second, "timing measurement must be deterministic");

    let path = snapshot_path();
    let committed = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing snapshot file {} ({e}); commit the placeholder", path.display()));
    let bless = std::env::var("SPARQ_BLESS").is_ok_and(|v| v == "1");
    let unblessed = committed.lines().next().is_some_and(|l| l.trim() == "# UNBLESSED");

    if bless || unblessed {
        std::fs::write(&path, render(&first)).expect("write blessed snapshot");
        eprintln!(
            "timing_snapshot: {} {} with {} measured entries — commit it; comparisons are \
             strict from the next run on",
            if unblessed { "bootstrapped" } else { "re-blessed" },
            path.display(),
            first.len()
        );
        return;
    }

    let pinned = parse(&committed);
    let got: std::collections::BTreeMap<_, _> = first.iter().cloned().collect();
    let want: std::collections::BTreeMap<_, _> = pinned.iter().cloned().collect();
    assert_eq!(
        got, want,
        "\ntiming model drifted from the committed snapshot. If the change is intentional, \
         re-bless with `SPARQ_BLESS=1 cargo test --test timing_snapshot` and commit \
         {}; otherwise find the regression before it skews autotune decisions and bench JSONs.",
        snapshot_path().display()
    );
}

#[test]
fn snapshot_covers_the_canonical_set() {
    // the set itself is part of the contract: every diagonal vmacsr
    // point, the native points the region admits (W4A4 has none), the
    // int16 baseline, and one of each boundary/pool/head stream
    let rows = measure();
    let keys: Vec<&str> = rows.iter().map(|(k, _)| k.as_str()).collect();
    for must in [
        "conv3x3-int16",
        "conv3x3-w1a1-vmacsr",
        "conv3x3-w2a2-vmacsr",
        "conv3x3-w3a3-vmacsr",
        "conv3x3-w4a4-vmacsr",
        "conv3x3-w1a1-native",
        "conv3x3-w2a2-native",
        "conv3x3-w3a3-native",
        "requant-e32-e16-pad1",
        "maxpool2-3x6x8-e16",
        "gapfc-32x16-e16",
    ] {
        assert!(keys.contains(&must), "snapshot set lost {must}");
    }
    assert!(!keys.contains(&"conv3x3-w4a4-native"), "W4A4 has no native plan");
    // and every measured stream actually cost cycles
    assert!(rows.iter().all(|(_, c)| *c > 0));
}
