//! The batched serving layer end-to-end (DESIGN.md §Serving):
//!
//! * **Determinism** — a batch of B distinct images produces
//!   bit-identical logits AND per-image cycles to B sequential
//!   single-image inferences through the same batched program, and the
//!   batch's only cycle saving is exactly the (B-1) amortized
//!   weight-pack preambles.
//! * **Backpressure** — flooding the slot-reservation ring until every
//!   frame is claimed-and-unconsumed yields typed
//!   `ServeError::QueueFull` rejections, counted in the metrics, while
//!   every accepted request still completes.

use sparq::config::ServeConfig;
use sparq::coordinator::{QnnBatchServer, ServeError};
use sparq::kernels::ProgramCache;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::{QnnGraph, QnnNet};
use sparq::runtime::SimQnnModel;
use sparq::sim::MachinePool;
use sparq::ProcessorConfig;

const SEED: u64 = 0x0BA7_C41D;

fn w2a2() -> QnnPrecision {
    QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
}

#[test]
fn batch_of_b_is_bit_identical_to_b_sequential_single_inferences() {
    const B: u32 = 4;
    let cache = ProgramCache::new();
    let cfg = ProcessorConfig::sparq();
    let graph = QnnGraph::sparq_cnn();
    let model = SimQnnModel::compile_batched(&cfg, &graph, w2a2(), SEED, &cache, B).unwrap();
    let pool = MachinePool::new();

    let net = QnnNet::from_seed(&graph, w2a2(), SEED).unwrap();
    let images: Vec<Vec<u64>> = (0..B as u64).map(|i| net.test_image(77 + i)).collect();
    let inputs: Vec<Vec<f32>> = images
        .iter()
        .map(|img| img.iter().map(|&v| v as f32).collect())
        .collect();

    // one batch of B distinct images
    let (batched, batch_total) = model.infer_batch(&pool, &inputs).unwrap();
    assert_eq!(batched.len(), B as usize);

    // B sequential single-image inferences through the SAME program
    let mut single_total = 0u64;
    let mut preambles = Vec::new();
    for (i, input) in inputs.iter().enumerate() {
        let (one, one_total) = model.infer_batch(&pool, std::slice::from_ref(input)).unwrap();
        // bit-identical logits AND per-image cycles
        assert_eq!(one[0].0, batched[i].0, "image {i}: logits diverged");
        assert_eq!(one[0].1, batched[i].1, "image {i}: cycles diverged");
        preambles.push(one_total - one[0].1);
        single_total += one_total;
    }
    // every sequential run paid the same preamble; the batch paid it once
    assert!(preambles.iter().all(|&p| p == preambles[0]));
    let preamble = preambles[0];
    assert!(preamble > 0, "the packed network must carry a weight-pack preamble");
    assert_eq!(
        single_total - batch_total,
        (B as u64 - 1) * preamble,
        "the batch must save exactly B-1 preambles and nothing else"
    );

    // and each image still agrees with the host golden network
    for (i, img) in images.iter().enumerate() {
        let golden = net.golden_forward(img).unwrap();
        assert_eq!(batched[i].0, golden.logits, "image {i} vs golden");
    }
}

#[test]
fn flooding_the_queue_past_capacity_is_typed_backpressure() {
    // tiny ring (queue_depth 2 / batch 2 -> 2 frames), one worker, a
    // long batching window: submissions from this thread are far faster
    // than a simulated inference, so every frame ends up
    // claimed-and-unconsumed and later submissions must see QueueFull
    let cache = ProgramCache::new();
    let serve = ServeConfig {
        workers: 1,
        batch_window_us: 1_000,
        queue_depth: 2,
        batch: 2,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        SEED,
        serve,
        &cache,
    )
    .unwrap();
    let image_len = server.image_len();
    let mut pending = Vec::new();
    let mut rejected = 0usize;
    // keep flooding until backpressure shows (bounded by the queue
    // depth + in-flight batches, this terminates fast)
    for i in 0..200usize {
        match server.submit(vec![(i % 4) as f32; image_len]) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::QueueFull) => {
                rejected += 1;
                if rejected >= 3 {
                    break;
                }
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected >= 3, "the bounded shard never pushed back");
    // every ACCEPTED request completes despite the flood
    let accepted = pending.len();
    for rx in pending {
        let r = rx.recv().expect("worker vanished").expect("accepted request must serve");
        assert!(r.batch >= 1 && r.batch <= 2);
        assert!(r.sim_cycles > 0);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, accepted);
    assert!(snap.rejected >= 3, "rejections must be counted in the metrics");
    assert_eq!(snap.errors, 0);
    assert!(snap.queue_depth_max >= 1, "the depth gauge must have seen queued requests");
    assert_eq!(snap.queue_depth, 0, "the queue must drain by shutdown");
    // fill histogram covers every executed batch
    assert_eq!(snap.batches, snap.batch_fill.iter().map(|&(_, n)| n).sum::<u64>());
    assert!(snap.batch_fill.iter().all(|&(k, _)| k >= 1 && k <= 2));
}

#[test]
fn concurrent_producers_share_batches_and_all_complete() {
    use std::sync::Arc;
    let cache = ProgramCache::new();
    let serve = ServeConfig {
        workers: 2,
        batch_window_us: 20_000,
        queue_depth: 128,
        batch: 4,
        ..ServeConfig::default()
    };
    let server = Arc::new(
        QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            SEED,
            serve,
            &cache,
        )
        .unwrap(),
    );
    let image_len = server.image_len();
    let mut handles = vec![];
    for i in 0..16usize {
        let s = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            s.infer(vec![(i % 4) as f32; image_len]).unwrap()
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let max_fill = results.iter().map(|r| r.batch).max().unwrap();
    assert!(max_fill >= 2, "no batching happened under concurrent load");
    let server = Arc::try_unwrap(server).ok().unwrap();
    let snap = server.shutdown();
    assert_eq!(snap.completed, 16);
    assert_eq!(snap.errors, 0);
    assert!(snap.batches < 16, "some requests must have shared a batch");
}
