//! Concurrency suite for the lock-free slot-reservation batch ring
//! (`sparq::coordinator::ring`, DESIGN.md §Serving).
//!
//! The in-module model checker enumerates every interleaving of the
//! seal/consume state machine over ONE frame word; these tests drive
//! the real thing with real threads across many frames: multi-producer
//! exactly-once delivery, the window-expiry vs last-writer seal race,
//! ring wraparound, dead-consumer backpressure, and close-under-load.
//!
//! `SPARQ_FUZZ_ITERS` scales the randomized cases (the nightly
//! deep-fuzz CI job raises it; the PR matrix runs the defaults).

use std::time::Duration;

use sparq::coordinator::ring::{BatchRing, Pop, PushError};
use sparq::testutil::{fuzz_iters, Prop};

/// Push with bounded retry on `Full` (the typed refusal hands the
/// item back, so a producer that *wants* to block can spin).
fn push_retry(ring: &BatchRing<u64>, mut v: u64) {
    loop {
        match ring.push(v) {
            Ok(_) => return,
            Err((PushError::Full, back)) => {
                v = back;
                std::thread::yield_now();
            }
            Err((PushError::Closed, _)) => panic!("ring closed mid-test"),
        }
    }
}

#[test]
fn multi_producer_delivery_is_exactly_once() {
    // 4 producers race claims into shared frames; every pushed item
    // must come out exactly once — no loss, no duplication, no torn
    // batch (fill always matches the drained item count).
    const PRODUCERS: u64 = 4;
    const PER: u64 = 64;
    let total = (PRODUCERS * PER) as usize;
    for _ in 0..fuzz_iters(4) {
        let ring: BatchRing<u64> = BatchRing::new(8, 4, Duration::from_micros(200));
        let ring_ref = &ring;
        let (got, fills_ok) = std::thread::scope(|s| {
            let consumer = s.spawn(move || {
                let mut got = Vec::with_capacity(total);
                let mut fills_ok = true;
                while got.len() < total {
                    match ring_ref.pop(Duration::from_millis(50)) {
                        Pop::Batch(items, meta) => {
                            fills_ok &= meta.fill as usize == items.len()
                                && (1..=4).contains(&meta.fill);
                            got.extend(items);
                        }
                        Pop::Idle => {}
                        Pop::Closed => break,
                    }
                }
                (got, fills_ok)
            });
            for p in 0..PRODUCERS {
                s.spawn(move || {
                    for k in 0..PER {
                        push_retry(ring_ref, p * 1000 + k);
                    }
                });
            }
            consumer.join().unwrap()
        });
        assert!(fills_ok, "every batch's fill must match its drained item count");
        let mut got = got;
        got.sort_unstable();
        let mut want: Vec<u64> =
            (0..PRODUCERS).flat_map(|p| (0..PER).map(move |k| p * 1000 + k)).collect();
        want.sort_unstable();
        assert_eq!(got, want, "delivery must be exactly once");
    }
}

#[test]
fn window_expiry_vs_last_writer_seal_race_is_exactly_once() {
    // Tiny randomized windows make both sealers win constantly; under
    // that race the ring must still deliver exactly once, with
    // contiguous batch sequence numbers and sane fills.
    Prop::new(0x5EA1_CA5E).runs(fuzz_iters(24)).check(|g| {
        let window = Duration::from_micros(g.range(0, 300));
        let batch = g.range(1, 4) as usize;
        let frames = 1usize << g.range(1, 3);
        let n = g.range(20, 120);
        let ring: BatchRing<u64> = BatchRing::new(frames, batch, window);
        let ring_ref = &ring;
        let got = std::thread::scope(|s| {
            let consumer = s.spawn(move || {
                let mut got = Vec::with_capacity(n as usize);
                let mut batches = 0u64;
                while got.len() < n as usize {
                    match ring_ref.pop(Duration::from_millis(50)) {
                        Pop::Batch(items, meta) => {
                            assert_eq!(
                                meta.seq, batches,
                                "a single consumer sees contiguous sequence numbers"
                            );
                            assert!(meta.fill >= 1 && meta.fill as usize <= batch);
                            assert_eq!(meta.fill as usize, items.len());
                            batches += 1;
                            got.extend(items);
                        }
                        Pop::Idle => {}
                        Pop::Closed => break,
                    }
                }
                got
            });
            let half = n / 2;
            s.spawn(move || {
                for v in 0..half {
                    push_retry(ring_ref, v);
                }
            });
            s.spawn(move || {
                for v in half..n {
                    push_retry(ring_ref, v);
                }
            });
            consumer.join().unwrap()
        });
        let mut got = got;
        got.sort_unstable();
        assert_eq!(got, (0..n).collect::<Vec<u64>>());
    });
}

#[test]
fn ring_wraparound_under_sustained_load() {
    // 2 frames x 2 slots, 200 riders: every frame index is reused ~50
    // times, so the generation tags must keep stale producers out of
    // recycled frames and the order must survive the wraps.
    let ring: BatchRing<u64> = BatchRing::new(2, 2, Duration::from_secs(10));
    let mut got = Vec::with_capacity(200);
    let mut batches = 0u64;
    let mut next = 0u64;
    while next < 200 {
        match ring.push(next) {
            Ok(_) => next += 1,
            Err((PushError::Full, _)) => match ring.pop(Duration::ZERO) {
                Pop::Batch(items, meta) => {
                    assert_eq!(meta.seq, batches, "frames consume in sequence order");
                    assert_eq!(meta.fill, 2, "the huge window means only full frames seal");
                    batches += 1;
                    got.extend(items);
                }
                other => panic!("a full ring must hold consumable batches, got {other:?}"),
            },
            Err((PushError::Closed, _)) => unreachable!("nobody closed the ring"),
        }
    }
    ring.close();
    loop {
        match ring.pop(Duration::ZERO) {
            Pop::Batch(items, meta) => {
                assert_eq!(meta.seq, batches);
                batches += 1;
                got.extend(items);
            }
            Pop::Closed => break,
            Pop::Idle => unreachable!("a closed ring never idles"),
        }
    }
    assert_eq!(got, (0..200).collect::<Vec<u64>>(), "order survives the wraparound");
    assert!(batches as usize > ring.frames(), "the ring must actually wrap");
}

#[test]
fn submits_during_consumer_death_see_typed_backpressure() {
    // A consumer that takes one batch and then "dies": pushes keep
    // landing until every frame is claimed-and-unconsumed, then the
    // refusal is typed `Full` — never a block, never a lost rider.  A
    // replacement consumer recovers the backlog exactly once.
    let ring: BatchRing<u64> = BatchRing::new(2, 2, Duration::from_secs(10));
    ring.push(0).unwrap();
    ring.push(1).unwrap();
    match ring.pop(Duration::ZERO) {
        Pop::Batch(items, _) => assert_eq!(items, vec![0, 1]),
        other => panic!("expected the first batch, got {other:?}"),
    }
    // the consumer is gone; capacity is frames * batch = 4 riders
    for v in 2..6 {
        assert!(ring.push(v).is_ok(), "rider {v} fits the dead-consumer backlog");
    }
    match ring.push(99) {
        Err((PushError::Full, item)) => assert_eq!(item, 99, "the item rides back typed"),
        other => panic!("expected Full, got {other:?}"),
    }
    // a replacement worker drains the backlog exactly once, in order
    let mut got = Vec::new();
    for _ in 0..2 {
        match ring.pop(Duration::ZERO) {
            Pop::Batch(items, _) => got.extend(items),
            other => panic!("expected a backlog batch, got {other:?}"),
        }
    }
    assert_eq!(got, vec![2, 3, 4, 5]);
    // and the freed frames accept work again before close refuses it
    assert!(ring.push(6).is_ok());
    ring.close();
    match ring.push(7) {
        Err((PushError::Closed, item)) => assert_eq!(item, 7),
        other => panic!("expected Closed, got {other:?}"),
    }
}

#[test]
fn close_under_concurrent_load_loses_no_accepted_rider() {
    // Producers hammer the ring while it closes mid-flight: every push
    // resolves typed (Ok / Full / Closed), and the drained multiset
    // must equal exactly the accepted pushes — the quiescence protocol
    // means no rider is accepted-then-dropped or invented.
    for round in 0..fuzz_iters(4) {
        let ring: BatchRing<u64> = BatchRing::new(4, 2, Duration::from_micros(50));
        let ring_ref = &ring;
        let (accepted, drained) = std::thread::scope(|s| {
            let consumer = s.spawn(move || {
                let mut drained = Vec::new();
                loop {
                    match ring_ref.pop(Duration::from_millis(5)) {
                        Pop::Batch(items, _) => drained.extend(items),
                        Pop::Idle => {}
                        Pop::Closed => return drained,
                    }
                }
            });
            let producers: Vec<_> = (0..3u64)
                .map(|p| {
                    s.spawn(move || {
                        let mut accepted = Vec::new();
                        for k in 0..400u64 {
                            let v = p * 1000 + k;
                            match ring_ref.push(v) {
                                Ok(_) => accepted.push(v),
                                Err((PushError::Full, _)) => std::thread::yield_now(),
                                Err((PushError::Closed, _)) => break,
                            }
                        }
                        accepted
                    })
                })
                .collect();
            // close mid-storm (vary the cut point a little per round)
            std::thread::sleep(Duration::from_micros(200 + 150 * (round % 8) as u64));
            ring.close();
            let mut accepted = Vec::new();
            for p in producers {
                accepted.extend(p.join().unwrap());
            }
            (accepted, consumer.join().unwrap())
        });
        let mut accepted = accepted;
        let mut drained = drained;
        accepted.sort_unstable();
        drained.sort_unstable();
        assert_eq!(
            drained, accepted,
            "the drained multiset must be exactly the accepted pushes"
        );
    }
}
