//! Property pins on the ULPPACK packing math the autotuner leans on:
//! quantize -> pack -> unpack -> dot round-trips over **every** (W, A)
//! in 1..=4 x 1..=4, both `RegionMode`s, and odd/even channel counts,
//! all against scalar oracles:
//!
//! 1. `unpack(pack(levels))` recovers the levels exactly, for both the
//!    activation layout and the swapped weight layout, on both
//!    containers.
//! 2. The packed-arithmetic dot (the hardware model
//!    `golden_packed_vmacsr`, at the container + spill cadence the
//!    region calculus plans) equals the exact integer conv
//!    (`golden_exact`) whenever the plan guarantees exactness — which
//!    is every Strict-mode plan, and every Paper-mode plan whose pair
//!    also admits strictly.
//! 3. Quantized levels always stay inside their (W, A) ranges, so the
//!    dot-field capacity argument the plans rest on actually applies.
//!
//! Odd channel counts get the explicit always-zero padding channel
//! (`qnn::graph::padded_c`) before packing — the same rule the
//! dataflow compiler applies — and the oracle sees the zero channel
//! too, so padding cannot silently change the dot.

//! Case counts: cheap defaults on PR CI; the nightly scheduled job
//! scales them via `SPARQ_FUZZ_ITERS` (`testutil::fuzz_iters`).

use sparq::kernels::workload::{golden_exact, golden_packed_vmacsr, ConvDims, Workload};
use sparq::qnn::graph::padded_c;
use sparq::testutil::{fuzz_iters, Gen, Prop};
use sparq::ulppack::{
    act_level_max, pack_activations, pack_weights, region, unpack_container, weight_level_max,
    Container, Quantizer, RegionMode,
};

/// Quantize random floats into a levels workload with `c_real`
/// channels padded to even, inside the (W, A) level ranges.
fn quantized_workload(g: &mut Gen, w_bits: u32, a_bits: u32, c_real: u32) -> Workload {
    let cp = padded_c(c_real);
    let dims = ConvDims { c: cp, h: 5, w: 5, co: 2, fh: 3, fw: 3 };
    let qa = Quantizer::for_activations(a_bits, 1.0);
    let qw = Quantizer::for_weights(w_bits, 1.0);
    let hw = (dims.h * dims.w) as usize;
    let fhw = (dims.fh * dims.fw) as usize;
    // real channels quantize floats; padding channels are explicit zeros
    let act: Vec<Vec<u64>> = (0..cp)
        .map(|c| {
            (0..hw)
                .map(|_| {
                    let x = g.f32() * 1.2 - 0.1; // overshoot both ends
                    if c < c_real {
                        qa.act_level(x)
                    } else {
                        0
                    }
                })
                .collect()
        })
        .collect();
    let wgt: Vec<Vec<Vec<u64>>> = (0..dims.co)
        .map(|_| {
            (0..cp)
                .map(|_| (0..fhw).map(|_| qw.weight_level(g.f32() * 2.4 - 1.2)).collect())
                .collect()
        })
        .collect();
    Workload { dims, w_bits, a_bits, act, wgt, act_f32: vec![], wgt_f32: vec![] }
}

#[test]
fn pack_unpack_roundtrip_both_layouts_every_precision() {
    Prop::new(0xF00D).runs(fuzz_iters(64)).check(|g| {
        let w_bits = g.range(1, 4) as u32;
        let a_bits = g.range(1, 4) as u32;
        let c_real = g.range(1, 6) as u32; // odd and even counts
        let container = *g.pick(&[Container::Ulp, Container::Lp]);
        let wl = quantized_workload(g, w_bits, a_bits, c_real);
        // skip combinations whose levels cannot fit the subfields at
        // all (e.g. A4 on ULP's 4-bit fields is fine: 15 fits; W4's 14
        // fits too — nothing in 1..=4 overflows a 4-bit field, so this
        // filter is vacuous but keeps the property honest if ranges grow)
        let s = container.shift();
        if act_level_max(a_bits) >= (1 << s) || weight_level_max(w_bits) >= (1 << s) {
            return;
        }
        let pa = pack_activations(&wl.act, container);
        for (pair, packed) in wl.act.chunks(2).zip(&pa) {
            for (i, &p) in packed.iter().enumerate() {
                let (lo, hi) = unpack_container(p, container);
                assert_eq!((lo, hi), (pair[0][i], pair[1][i]), "activation layout");
            }
        }
        // the weight layout swaps the halves: low field holds the ODD
        // channel, high field the even one
        let pw = pack_weights(&wl.wgt, container);
        for (per_o, packed_o) in wl.wgt.iter().zip(&pw) {
            for (pair, packed) in per_o.chunks(2).zip(packed_o) {
                for (i, &p) in packed.iter().enumerate() {
                    let (lo, hi) = unpack_container(p, container);
                    assert_eq!((lo, hi), (pair[1][i], pair[0][i]), "weight layout swaps");
                }
            }
        }
    });
}

#[test]
fn quantized_levels_stay_in_range() {
    Prop::new(0xA11).runs(fuzz_iters(64)).check(|g| {
        let w_bits = g.range(1, 4) as u32;
        let a_bits = g.range(1, 4) as u32;
        let wl = quantized_workload(g, w_bits, a_bits, g.range(1, 6) as u32);
        let amax = act_level_max(a_bits);
        let wmax = weight_level_max(w_bits);
        assert!(wl.act.iter().flatten().all(|&v| v <= amax));
        assert!(wl.wgt.iter().flatten().flatten().all(|&v| v <= wmax));
    });
}

#[test]
fn packed_dot_matches_the_scalar_oracle_wherever_the_plan_is_exact() {
    // exhaustive over the whole precision grid x both modes x odd/even
    // channel counts, a few random tensors each
    for w_bits in 1..=4u32 {
        for a_bits in 1..=4u32 {
            for mode in [RegionMode::Strict, RegionMode::Paper] {
                for c_real in [3u32, 4] {
                    let seed = 0x5EED
                        ^ ((w_bits as u64) << 8)
                        ^ ((a_bits as u64) << 16)
                        ^ ((c_real as u64) << 24)
                        ^ (((mode == RegionMode::Paper) as u64) << 32);
                    let mut g = Gen::new(seed);
                    for _ in 0..fuzz_iters(3) {
                        let wl = quantized_workload(&mut g, w_bits, a_bits, c_real);
                        let issues = wl.dims.issues_per_output();
                        let Some(plan) = region::plan_vmacsr(w_bits, a_bits, issues, mode) else {
                            // no plan: only legal outside Paper mode
                            // (Strict refuses pairs like W4A4)
                            assert_eq!(
                                mode,
                                RegionMode::Strict,
                                "paper mode must admit every 1..=4 pair on LP"
                            );
                            continue;
                        };
                        let packed =
                            golden_packed_vmacsr(&wl, plan.container, plan.spill_every);
                        let exact = golden_exact(&wl);
                        if plan.exact {
                            assert_eq!(
                                packed, exact,
                                "W{w_bits}A{a_bits} {mode:?} c={c_real}: exact plan diverged"
                            );
                        } else {
                            // non-exact plans still produce in-range
                            // container sums (the spill cadence bounds
                            // the narrow accumulator by construction)
                            assert_eq!(packed.len(), exact.len());
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn strict_plans_cover_the_paper_headline_points() {
    // the pins the autotuner's candidate set relies on: W2A2 is exact
    // on ULP (the 3.2x point), W4A4 only runs in Paper mode on LP (the
    // 1.7x point), and every Strict plan self-reports exact
    let issues = 8 * 9;
    let p22 = region::plan_vmacsr(2, 2, issues, RegionMode::Paper).unwrap();
    assert_eq!(p22.container, Container::Ulp);
    assert!(p22.exact);
    let p44 = region::plan_vmacsr(4, 4, issues, RegionMode::Paper).unwrap();
    assert_eq!(p44.container, Container::Lp);
    assert!(!p44.exact);
    assert!(region::plan_vmacsr(4, 4, issues, RegionMode::Strict).is_none());
    for w in 1..=4 {
        for a in 1..=4 {
            if let Some(p) = region::plan_vmacsr(w, a, issues, RegionMode::Strict) {
                assert!(p.exact, "strict plans are exact by definition");
            }
        }
    }
}
