//! Property test pinning the ISA against drift: for EVERY `VOp`
//! variant (enumerated through an exhaustive `match` — adding an op
//! without extending this test fails to compile) and every encodable
//! operand form, `encode -> decode -> encode` must be a fixpoint,
//! `decode` must reproduce the instruction modulo dynamic fields
//! (addresses / scalar values / AVL, which live in scalar registers on
//! real hardware), and `disasm` must render the op's mnemonic for
//! both the original and the decoded instruction.

use sparq::isa::{decode, disasm, encode, Lmul, Sew, VInst, VOp};
use sparq::testutil::Prop;

/// Every `VOp`, via an exhaustive match (the drift pin).
fn all_vops() -> Vec<VOp> {
    let known = [
        VOp::Add,
        VOp::Sub,
        VOp::And,
        VOp::Or,
        VOp::Xor,
        VOp::Sll,
        VOp::Srl,
        VOp::Sra,
        VOp::Min,
        VOp::Max,
        VOp::Mv,
        VOp::WAdduWv,
        VOp::NSrl,
        VOp::Mul,
        VOp::Mulh,
        VOp::Mulhu,
        VOp::Macc,
        VOp::Nmsac,
        VOp::Macsr,
        VOp::MacsrCfg,
        VOp::FAdd,
        VOp::FMul,
        VOp::FMacc,
        VOp::SlideDown,
        VOp::SlideUp,
    ];
    // exhaustiveness: a new VOp variant makes this match non-exhaustive
    for op in known {
        match op {
            VOp::Add
            | VOp::Sub
            | VOp::And
            | VOp::Or
            | VOp::Xor
            | VOp::Sll
            | VOp::Srl
            | VOp::Sra
            | VOp::Min
            | VOp::Max
            | VOp::Mv
            | VOp::WAdduWv
            | VOp::NSrl
            | VOp::Mul
            | VOp::Mulh
            | VOp::Mulhu
            | VOp::Macc
            | VOp::Nmsac
            | VOp::Macsr
            | VOp::MacsrCfg
            | VOp::FAdd
            | VOp::FMul
            | VOp::FMacc
            | VOp::SlideDown
            | VOp::SlideUp => {}
        }
    }
    known.to_vec()
}

/// Ops with a .vi (OPIVI) encoding.
fn has_vi(op: VOp) -> bool {
    // the OPI space is exactly the set with immediate forms
    sparq::isa::encode::funct6_opi(op).is_some()
}

fn check_roundtrip(inst: VInst) {
    let Ok(word) = encode(&inst) else {
        panic!("{inst}: constructible form must encode");
    };
    let back = decode(word).unwrap_or_else(|e| panic!("{inst} ({word:#010x}): {e}"));
    // encode(decode(encode(i))) == encode(i): the fixpoint
    assert_eq!(encode(&back).unwrap(), word, "{inst}: encode/decode not a fixpoint");
    // register and immediate fields survive; dynamic fields decode to
    // 0, and vmv.v.* hard-wires vs2 to v0 in the word (vmerge vm=1)
    let vs2_of = |op: VOp, vs2: u8| if op == VOp::Mv { 0 } else { vs2 };
    match (inst, back) {
        (VInst::OpVV { op, vd, vs2, vs1 }, VInst::OpVV { op: o2, vd: d2, vs2: s2, vs1: s1 }) => {
            assert_eq!((op, vd, vs2_of(op, vs2), vs1), (o2, d2, s2, s1), "{inst}");
        }
        (VInst::OpVX { op, vd, vs2, .. }, VInst::OpVX { op: o2, vd: d2, vs2: s2, rs1 }) => {
            assert_eq!((op, vd, vs2_of(op, vs2), 0u64), (o2, d2, s2, rs1), "{inst}");
        }
        (VInst::OpVI { op, vd, vs2, imm }, VInst::OpVI { op: o2, vd: d2, vs2: s2, imm: i2 }) => {
            assert_eq!((op, vd, vs2_of(op, vs2), imm), (o2, d2, s2, i2), "{inst}");
        }
        (a, b) => panic!("{a} decoded to a different form: {b}"),
    }
    // disassembly names the op for both the original and the decoded
    let m = inst.vop().unwrap().mnemonic();
    assert!(disasm(&inst).starts_with(m), "disasm({inst}) missing mnemonic {m}");
    assert!(disasm(&back).starts_with(m), "disasm(decoded {back}) missing mnemonic {m}");
}

#[test]
fn every_vop_roundtrips_in_every_encodable_form() {
    for op in all_vops() {
        check_roundtrip(VInst::OpVV { op, vd: 1, vs2: 2, vs1: 3 });
        check_roundtrip(VInst::OpVX { op, vd: 1, vs2: 2, rs1: 0 });
        if has_vi(op) {
            check_roundtrip(VInst::OpVI { op, vd: 1, vs2: 2, imm: 5 });
        } else {
            assert!(
                encode(&VInst::OpVI { op, vd: 1, vs2: 2, imm: 5 }).is_err(),
                "{op:?}: .vi form must be a typed encode error"
            );
        }
    }
}

#[test]
fn random_fields_roundtrip_over_every_op() {
    Prop::new(0x15A_0B0B).runs(600).check(|g| {
        let ops = all_vops();
        let op = *g.pick(&ops);
        let vd = g.below(32) as u8;
        let vs2 = g.below(32) as u8;
        match g.below(3) {
            0 => check_roundtrip(VInst::OpVV { op, vd, vs2, vs1: g.below(32) as u8 }),
            1 => check_roundtrip(VInst::OpVX { op, vd, vs2, rs1: 0 }),
            _ => {
                if has_vi(op) {
                    // uimm5 for shifts/slides, simm5 for the rest
                    let imm = if matches!(
                        op,
                        VOp::Sll | VOp::Srl | VOp::Sra | VOp::NSrl | VOp::SlideDown | VOp::SlideUp
                    ) {
                        g.below(32) as i8
                    } else {
                        g.irange(-16, 15) as i8
                    };
                    check_roundtrip(VInst::OpVI { op, vd, vs2, imm });
                }
            }
        }
    });
}

#[test]
fn memory_and_config_forms_roundtrip_with_disasm() {
    for eew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
        for v in [0u8, 7, 31] {
            let l = VInst::Load { eew, vd: v, addr: 0 };
            assert_eq!(decode(encode(&l).unwrap()).unwrap(), l);
            assert!(disasm(&l).starts_with(&format!("vle{}", eew.bits())));
            let s = VInst::Store { eew, vs3: v, addr: 0 };
            assert_eq!(decode(encode(&s).unwrap()).unwrap(), s);
            assert!(disasm(&s).starts_with(&format!("vse{}", eew.bits())));
        }
    }
    for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
        for lmul in [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8] {
            let i = VInst::SetVl { avl: 0, sew, lmul };
            assert_eq!(decode(encode(&i).unwrap()).unwrap(), i);
            assert!(disasm(&i).contains(&format!("{sew},{lmul}")));
        }
    }
}

#[test]
fn vmacsr_keeps_its_published_slot() {
    // the paper's Fig. 3 placement (funct6 right after vmacc) and the
    // vnsrl narrowing slot are part of the ISA contract
    let mac = encode(&VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 0 }).unwrap();
    assert_eq!(mac >> 26, 0b101110);
    let nsrl = encode(&VInst::OpVI { op: VOp::NSrl, vd: 1, vs2: 2, imm: 0 }).unwrap();
    assert_eq!(nsrl >> 26, 0b101100);
}
