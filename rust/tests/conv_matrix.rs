//! Integration: the conv2d equivalence matrix across variants,
//! processors, shapes and precisions — every packed implementation must
//! agree with the plain integer convolution wherever the calculus says
//! it is exact, and all variants must agree with *each other* through
//! the shared oracle.  No artifacts needed.

use sparq::arch::ProcessorConfig;
use sparq::kernels::workload::{golden_exact, golden_fp32, golden_mod};
use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
use sparq::testutil::Prop;
use sparq::ulppack::region;
use sparq::ulppack::RegionMode;

fn dims_cases() -> Vec<ConvDims> {
    vec![
        ConvDims { c: 2, h: 4, w: 6, co: 1, fh: 1, fw: 1 },
        ConvDims { c: 4, h: 8, w: 9, co: 3, fh: 3, fw: 3 },
        ConvDims { c: 8, h: 12, w: 300, co: 2, fh: 5, fw: 5 }, // strip-mined
        ConvDims { c: 16, h: 13, w: 13, co: 2, fh: 7, fw: 7 },
        ConvDims { c: 6, h: 9, w: 8, co: 2, fh: 3, fw: 5 }, // non-square kernel
    ]
}

#[test]
fn int16_matches_oracle_on_all_shapes() {
    for d in dims_cases() {
        let wl = Workload::random(d, 6, 6, 0xD1);
        let run = run_conv(&ProcessorConfig::sparq(), &wl, ConvVariant::Int16).unwrap();
        assert_eq!(
            run.out.read_ints(&run.machine.mem).unwrap(),
            golden_mod(&wl, 16),
            "{d:?}"
        );
    }
}

#[test]
fn fp32_matches_ordered_golden_on_all_shapes() {
    for d in dims_cases() {
        let wl = Workload::random(d, 4, 4, 0xF3);
        let run = run_conv(&ProcessorConfig::ara(), &wl, ConvVariant::Fp32).unwrap();
        assert_eq!(run.out.read_f32(&run.machine.mem).unwrap(), golden_fp32(&wl), "{d:?}");
    }
}

#[test]
fn every_strict_precision_exact_on_every_shape() {
    let sparq = ProcessorConfig::sparq();
    let ara = ProcessorConfig::ara();
    for d in dims_cases() {
        for w in 1..=4u32 {
            for a in 1..=4u32 {
                let wl = Workload::random(d, w, a, (w * 31 + a) as u64);
                let oracle = golden_exact(&wl);
                if region::plan_vmacsr(w, a, d.issues_per_output(), RegionMode::Strict).is_some() {
                    let run = run_conv(
                        &sparq,
                        &wl,
                        ConvVariant::Vmacsr { w_bits: w, a_bits: a, mode: RegionMode::Strict },
                    )
                    .unwrap();
                    assert_eq!(
                        run.out.read_ints(&run.machine.mem).unwrap(),
                        oracle,
                        "vmacsr W{w}A{a} {d:?}"
                    );
                }
                if region::plan_native(w, a).is_some() {
                    let run = run_conv(&ara, &wl, ConvVariant::Native { w_bits: w, a_bits: a })
                        .unwrap();
                    assert_eq!(
                        run.out.read_ints(&run.machine.mem).unwrap(),
                        oracle,
                        "native W{w}A{a} {d:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn property_offline_and_runtime_packing_agree() {
    use sparq::kernels::{run_conv_opts, EngineOpts};
    Prop::new(0xB00).runs(6).check(|g| {
        let f = *g.pick(&[1u32, 3, 5]);
        let d = ConvDims {
            c: 2 * g.range(1, 4) as u32,
            h: f + g.range(2, 6) as u32,
            w: f + g.range(2, 20) as u32,
            co: g.range(1, 3) as u32,
            fh: f,
            fw: f,
        };
        let wl = Workload::random(d, 2, 2, g.next_u64());
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let cfg = ProcessorConfig::sparq();
        let rt = run_conv(&cfg, &wl, v).unwrap();
        let off = run_conv_opts(
            &cfg,
            &wl,
            v,
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        )
        .unwrap();
        assert_eq!(
            rt.out.read_ints(&rt.machine.mem).unwrap(),
            off.out.read_ints(&off.machine.mem).unwrap(),
            "{d:?}"
        );
        // and offline is never slower
        assert!(off.report.stats.cycles <= rt.report.stats.cycles);
    });
}

#[test]
fn property_lane_count_never_changes_results() {
    Prop::new(0x1A) .runs(4).check(|g| {
        let d = ConvDims { c: 4, h: 9, w: 40, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, g.next_u64());
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let mut outs = Vec::new();
        let mut cycles = Vec::new();
        for lanes in [1u32, 4, 8] {
            let cfg = ProcessorConfig::sparq().with_lanes(lanes);
            let run = run_conv(&cfg, &wl, v).unwrap();
            outs.push(run.out.read_ints(&run.machine.mem).unwrap());
            cycles.push(run.report.stats.cycles);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
        // more lanes, fewer (or equal) cycles
        assert!(cycles[0] >= cycles[1] && cycles[1] >= cycles[2], "{cycles:?}");
    });
}

#[test]
fn speedup_grows_monotonically_with_packing_headroom() {
    // fewer bits -> more headroom -> faster (vmacsr, same dims)
    let d = ConvDims { c: 16, h: 16, w: 70, co: 2, fh: 7, fw: 7 };
    let sparq = ProcessorConfig::sparq();
    let mut last = u64::MAX;
    for (w, a) in [(4u32, 4u32), (3, 3), (2, 2)] {
        let wl = Workload::random(d, w, a, 5);
        let run = run_conv(
            &sparq,
            &wl,
            ConvVariant::Vmacsr { w_bits: w, a_bits: a, mode: RegionMode::Paper },
        )
        .unwrap();
        assert!(
            run.report.stats.cycles <= last,
            "W{w}A{a} slower than higher precision"
        );
        last = run.report.stats.cycles;
    }
}

#[test]
fn adversarial_all_max_data_still_exact_in_strict_region() {
    let d = ConvDims { c: 8, h: 10, w: 12, co: 2, fh: 3, fw: 3 };
    let mut wl = Workload::random(d, 2, 2, 1);
    for row in wl.act.iter_mut() {
        row.iter_mut().for_each(|v| *v = 3); // max A2 level
    }
    for o in wl.wgt.iter_mut() {
        for c in o.iter_mut() {
            c.iter_mut().for_each(|v| *v = 2); // max W2 level
        }
    }
    let run = run_conv(
        &ProcessorConfig::sparq(),
        &wl,
        ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict },
    )
    .unwrap();
    assert_eq!(run.out.read_ints(&run.machine.mem).unwrap(), golden_exact(&wl));
}
