//! The chaos suite: deterministic fault injection against both serving
//! paths (DESIGN.md §Robustness).
//!
//! Every test here follows the same contract the harness was built for:
//!
//! * **No client ever hangs.**  Every submitted request resolves to a
//!   typed outcome within a bounded wait (`recv_timeout` — a timeout is
//!   a test failure, not a retry).
//! * **Same seed, same run.**  A seeded [`FaultPlan`] replays the exact
//!   fault sequence, so outcome multisets, error counts, and supervisor
//!   restart counts are asserted equal across two runs of the same
//!   scenario.
//!
//! `SPARQ_CHAOS_ITERS` scales the storm load (see
//! `sparq::testutil::chaos_iters`); the nightly deep-fuzz CI job raises
//! it, the PR matrix runs the defaults.

use std::sync::Arc;
use std::time::{Duration, Instant};

use sparq::config::ServeConfig;
use sparq::coordinator::{
    chaos_factory, fault, CallSel, ChaosSpec, Executor, FaultAction, FaultPlan, FaultRule,
    QnnBatchServer, ServeError, Server,
};
use sparq::kernels::ProgramCache;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::QnnGraph;
use sparq::ProcessorConfig;

/// Batch-1 mock: logits = [sum(image), -sum(image)], instant.
struct Mock;

impl Executor for Mock {
    fn batch(&self) -> usize {
        1
    }
    fn image_len(&self) -> usize {
        4
    }
    fn classes(&self) -> usize {
        2
    }
    fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
        let s: f32 = data.iter().sum();
        Ok(vec![s, -s])
    }
}

fn mock_factory() -> sparq::coordinator::ExecutorFactory {
    Box::new(|| Ok(Box::new(Mock) as Box<dyn Executor>))
}

/// The typed outcome class of one storm request.  The injected action
/// at global call index i is a pure function of the seed, so this
/// sequence must replay identically — but the *worker id* embedded in
/// the error text is a thread race, so we classify instead of
/// comparing raw strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Outcome {
    Ok,
    Kill,
    Panic,
    Error,
    Other,
}

fn classify(r: &Result<sparq::coordinator::InferResult, ServeError>) -> Outcome {
    match r {
        Ok(_) => Outcome::Ok,
        Err(ServeError::Worker(msg)) if fault::is_kill(msg) => Outcome::Kill,
        Err(ServeError::Worker(msg)) if msg.contains("injected panic") => Outcome::Panic,
        Err(ServeError::Worker(msg)) if msg.contains("injected error") => Outcome::Error,
        Err(_) => Outcome::Other,
    }
}

/// One full storm run: n sequential requests through a 2-worker server
/// whose executors all consult the same seeded plan.  Returns the
/// per-request outcome sequence and the final restart count.
fn run_storm(seed: u64, n: u32) -> (Vec<Outcome>, u64) {
    let plan = Arc::new(FaultPlan::seeded(seed, ChaosSpec::storm()));
    let cfg = ServeConfig {
        workers: 2,
        batch_window_us: 10,
        queue_depth: 64,
        // kills cannot outnumber calls, so a budget of n can never be
        // exhausted — the pool always comes back (`SPARQ_CHAOS_ITERS`
        // raises n well past the default in the nightly job)
        restart_budget: n,
        restart_backoff_us: 100,
        ..ServeConfig::default()
    };
    let server =
        Server::start(chaos_factory(mock_factory(), Arc::clone(&plan)), cfg, 0).unwrap();
    let mut outcomes = Vec::with_capacity(n as usize);
    for i in 0..n {
        let rx = server
            .submit(vec![i as f32, 1.0, 0.0, 0.0])
            .expect("storm submits must be accepted (budget is ample)");
        // a bounded wait IS the no-hang assertion
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} hung — no reply within 10s"));
        outcomes.push(classify(&r));
    }
    assert_eq!(
        plan.calls(),
        n as u64,
        "sequential batch-1 clients consume exactly one plan call per request"
    );
    // every kill costs exactly one respawn; wait for the supervisor to
    // catch up with the last one before freezing the count
    let kills = outcomes.iter().filter(|&&o| o == Outcome::Kill).count() as u64;
    let t0 = Instant::now();
    while server.health().restarts < kills {
        assert!(t0.elapsed() < Duration::from_secs(5), "supervisor never replaced the dead workers");
        std::thread::sleep(Duration::from_millis(1));
    }
    let restarts = server.health().restarts;
    server.shutdown();
    (outcomes, restarts)
}

#[test]
fn storm_load_completes_typed_and_replays_bit_identically() {
    let n = sparq::testutil::chaos_iters(500);
    let (a, restarts_a) = run_storm(0xC0FFEE, n);
    let (b, restarts_b) = run_storm(0xC0FFEE, n);

    // zero client hangs is asserted inside run_storm; here: the run
    // actually exercised every failure mode it claims to cover
    let kills = a.iter().filter(|&&o| o == Outcome::Kill).count();
    let panics = a.iter().filter(|&&o| o == Outcome::Panic).count();
    let errors = a.iter().filter(|&&o| o == Outcome::Error).count();
    let oks = a.iter().filter(|&&o| o == Outcome::Ok).count();
    assert!(kills > 0, "the storm must kill workers");
    assert!(panics > 0, "the storm must panic executors");
    assert!(errors > 0, "the storm must inject typed errors");
    assert!(oks > 0, "most requests still serve");
    assert!(a.iter().all(|&o| o != Outcome::Other), "only typed storm outcomes may appear");
    assert_eq!(restarts_a, kills as u64, "every kill costs exactly one supervisor respawn");

    // replay: the same seed reproduces the same per-request outcome
    // sequence and the same restart count
    assert_eq!(a, b, "same seed must replay the same outcome sequence");
    assert_eq!(restarts_a, restarts_b);
}

#[test]
fn different_seeds_give_different_storms() {
    let n = sparq::testutil::chaos_iters(500).min(500);
    let (a, _) = run_storm(1, n);
    let (b, _) = run_storm(2, n);
    assert_ne!(a, b, "distinct seeds should not produce identical storms");
}

#[test]
fn slow_executor_sheds_expired_requests_without_executing_them() {
    // every executed batch is delayed 100ms; requests behind the first
    // carry 20ms deadlines, so they expire in the queue and must be
    // shed typed — and shed requests must not consume fault-plan calls
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::Always,
        action: FaultAction::Delay(100_000),
    }]));
    let cfg = ServeConfig {
        workers: 1,
        batch_window_us: 10,
        queue_depth: 16,
        ..ServeConfig::default()
    };
    let server =
        Server::start(chaos_factory(mock_factory(), Arc::clone(&plan)), cfg, 0).unwrap();
    let r0 = server.submit_with_deadline(vec![1.0; 4], None).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // the worker takes r0
    let pending: Vec<_> = (0..5)
        .map(|_| {
            server
                .submit_with_deadline(vec![2.0; 4], Some(Duration::from_millis(20)))
                .unwrap()
        })
        .collect();
    assert!(r0.recv_timeout(Duration::from_secs(5)).expect("r0 hung").is_ok());
    for rx in pending {
        match rx.recv_timeout(Duration::from_secs(5)).expect("shed request hung") {
            Err(ServeError::Deadline) => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
    }
    assert_eq!(plan.calls(), 1, "shed requests must never reach the executor");
    let snap = server.shutdown();
    assert_eq!(snap.deadline_shed, 5);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.errors, 0);
}

#[test]
fn dead_pool_fails_fast_instead_of_queueing_forever() {
    // one worker, killed on every call, zero restart budget: after the
    // first (typed) failure the pool is dead for good and submit must
    // start refusing with NoWorkers — no request may ever hang
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::Always,
        action: FaultAction::Kill,
    }]));
    let cfg = ServeConfig {
        workers: 1,
        batch_window_us: 10,
        queue_depth: 16,
        restart_budget: 0,
        ..ServeConfig::default()
    };
    let server = Server::start(chaos_factory(mock_factory(), plan), cfg, 0).unwrap();
    match server.infer(vec![1.0; 4]) {
        Err(ServeError::Worker(msg)) => assert!(fault::is_kill(&msg), "{msg}"),
        other => panic!("expected the kill to surface typed, got {other:?}"),
    }
    // the death is asynchronous; poll until submit fails fast.  A
    // request accepted in the race window must still resolve typed
    // (the supervisor terminally drains the queue).
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(2), "submit never started failing fast");
        match server.submit(vec![1.0; 4]) {
            Err(ServeError::NoWorkers) => break,
            Ok(rx) => match rx.recv_timeout(Duration::from_secs(5)) {
                Ok(Err(_)) | Err(_) => {} // typed failure or closed channel — never a hang
                Ok(Ok(_)) => panic!("a dead pool cannot serve"),
            },
            Err(e) => panic!("unexpected {e:?}"),
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let h = server.health();
    assert_eq!(h.alive, 0);
    assert!(h.degraded);
    assert!(server.metrics.snapshot().no_workers > 0);
    server.shutdown();
}

fn w2a2() -> QnnPrecision {
    QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
}

/// One single-worker breaker scenario run: the worker fails its first
/// two batches (trip at threshold 2), heals on the third.  A single
/// worker over the shared ring makes every local call index
/// deterministic, so the scenario replays exactly.  Returns
/// (per-request outcomes, trips, retries, worker-0 errors).
fn run_breaker(cache: &ProgramCache) -> (Vec<bool>, u64, u64, u64) {
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: Some(0),
        when: CallSel::Range(0, 2),
        action: FaultAction::Error,
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 1,
        batch_window_us: 50,
        queue_depth: 16,
        breaker_threshold: 2,
        probation_us: 60_000_000, // the alive-only fallback keeps serving anyway
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    let mut oks = Vec::new();
    // batch 1 + one worker + a sequential client pin the local call
    // indices:
    //   req1 -> call 0 Error -> failover re-queues (retry 1)
    //        -> call 1 Error -> trip at threshold 2, retry exhausted,
    //           the client sees the SECOND failure typed
    //   req2 -> call 2 clean -> Ok, the success heals the breaker
    //   req3 -> call 3 clean -> Ok
    let r1 = server.submit(image.clone()).expect("submit");
    match r1.recv_timeout(Duration::from_secs(10)).expect("request hung") {
        Err(ServeError::Worker(msg)) => assert!(msg.contains("injected error"), "{msg}"),
        other => panic!("the retry-exhausted request must surface typed, got {other:?}"),
    }
    oks.push(false);
    for _ in 0..2 {
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        oks.push(r.is_ok());
    }
    let h = server.health();
    assert!(h.shards[0].alive);
    assert!(
        !h.shards[0].ejected,
        "a success must clear the probation window, not just the counter"
    );
    assert_eq!(h.shards[0].consecutive_errors, 0, "a success must heal the breaker");
    let shard0_errors = h.shards[0].errors;
    let snap = server.shutdown();
    (oks, snap.breaker_trips, snap.retries, shard0_errors)
}

#[test]
fn breaker_trips_at_threshold_and_a_success_heals_it() {
    let cache = ProgramCache::new();
    let (oks, trips, retries, shard0_errors) = run_breaker(&cache);
    assert_eq!(oks, vec![false, true, true]);
    assert_eq!(trips, 1, "two consecutive failures trip a threshold-2 breaker once");
    assert_eq!(retries, 1, "the first failure fails over exactly once");
    assert_eq!(shard0_errors, 2);
    // replay: the rule-driven scenario is deterministic end to end
    // (the second start hits the program cache, so it is cheap)
    let (oks2, trips2, retries2, shard0_errors2) = run_breaker(&cache);
    assert_eq!(oks, oks2);
    assert_eq!((trips, retries, shard0_errors), (trips2, retries2, shard0_errors2));
}

#[test]
fn ejected_worker_pauses_and_probation_readmits_it() {
    // worker 0 fails every batch it executes; threshold 1 ejects it on
    // the first failure.  While worker 1 is healthy the ejected worker
    // must PAUSE consuming from the shared ring (clients keep getting
    // Ok answers via failover), and probation expiry must re-admit it —
    // its next consumed batch is the probe, which fails again here and
    // re-trips the breaker.
    let cache = ProgramCache::new();
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: Some(0),
        when: CallSel::Always,
        action: FaultAction::Error,
    }]));
    let serve = ServeConfig {
        workers: 2,
        batch: 1,
        batch_window_us: 50,
        queue_depth: 16,
        breaker_threshold: 1,
        probation_us: 500_000,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    // which worker consumes each batch is a scheduling race over the
    // shared ring, so poll: submit until worker 0 has eaten (and
    // failed) at least one batch.  Failover must hide every failure.
    let t0 = Instant::now();
    while server.health().shards[0].errors == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker 0 never consumed a batch");
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        assert!(r.is_ok(), "failover must hide the ejected worker's failure: {r:?}");
    }
    let errors_before = server.health().shards[0].errors;
    assert!(server.health().breaker_trips >= 1, "a threshold-1 breaker trips on first failure");
    // while ejected (probation 500ms) the worker consumes nothing:
    // a burst of requests all succeeds and its error count freezes
    for _ in 0..8 {
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        assert!(r.is_ok(), "worker 1 serves alone while 0 sits out: {r:?}");
    }
    assert_eq!(
        server.health().shards[0].errors,
        errors_before,
        "an ejected worker must not consume from the ring while a healthy peer can"
    );
    // probation expiry re-admits it: its next batch is the probe
    std::thread::sleep(Duration::from_millis(600));
    let t1 = Instant::now();
    while server.health().shards[0].errors == errors_before {
        assert!(t1.elapsed() < Duration::from_secs(10), "probation never re-admitted worker 0");
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        assert!(r.is_ok(), "failover must hide the probe failure too: {r:?}");
    }
    let h = server.health();
    assert!(h.shards[0].errors > errors_before, "the probe reached the failing worker");
    assert!(h.breaker_trips >= 2, "the failed probe re-trips the breaker");
    let snap = server.shutdown();
    assert_eq!(snap.errors, 0, "no failure ever reached a client typed");
    assert!(snap.retries >= 2, "every worker-0 failure failed over");
}

#[test]
fn killed_shard_fails_over_and_stays_dead() {
    // `GlobalNth(0)` kills whichever worker executes the first batch —
    // over a shared ring "the worker that got the request" is a
    // scheduling race, so the kill targets the global call index, not
    // a worker id.  The rider fails over to the survivor.
    let cache = ProgramCache::new();
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::GlobalNth(0),
        action: FaultAction::Kill,
    }]));
    let serve = ServeConfig {
        workers: 2,
        batch: 1,
        batch_window_us: 50,
        queue_depth: 16,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    // req1's batch is killed mid-execution; the request must fail over
    // to the surviving worker and come back Ok — never hang, never err
    for i in 0..4 {
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        assert!(r.is_ok(), "request {i} must survive the shard kill: {r:?}");
    }
    // the death is asynchronous (the worker unwinds after answering)
    let t0 = Instant::now();
    while server.health().alive != 1 {
        assert!(t0.elapsed() < Duration::from_secs(5), "the killed worker never went down");
        std::thread::sleep(Duration::from_millis(1));
    }
    let h = server.health();
    assert_eq!(h.alive, 1, "the killed shard stays dead (no supervisor on the batch path)");
    assert_eq!(h.shards.iter().filter(|s| !s.alive).count(), 1);
    let snap = server.shutdown();
    assert!(snap.retries >= 1, "the killed batch's request must have failed over");
    assert_eq!(snap.errors, 0, "failover hid the kill from every client");
}

#[test]
fn failover_sheds_expired_requests_typed() {
    // regression: fail_over used to re-queue requests whose deadline
    // had already passed during the failed execution — they burned a
    // ring slot only to be shed on the next pop.  An expired rider must
    // be answered `Deadline` (counted in deadline_shed) AT failover
    // time; only live riders re-enter the ring.
    let cache = ProgramCache::new();
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: Some(0),
        when: CallSel::Nth(0),
        action: FaultAction::SlowError(50_000), // 50ms burn, then fail
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 2,
        batch_window_us: 100_000,
        queue_depth: 16,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    // both riders land in the same batch-2 frame (the second write
    // seals it); the injected slow error outlives B's 20ms deadline
    let rx_a = server.submit_with_deadline(image.clone(), None).expect("submit a");
    let rx_b = server
        .submit_with_deadline(image.clone(), Some(Duration::from_millis(20)))
        .expect("submit b");
    match rx_b.recv_timeout(Duration::from_secs(10)).expect("b hung") {
        Err(ServeError::Deadline) => {}
        other => panic!("the expired rider must be shed typed at failover, got {other:?}"),
    }
    let a = rx_a.recv_timeout(Duration::from_secs(10)).expect("a hung");
    assert!(a.is_ok(), "the live rider's retry must serve: {a:?}");
    let snap = server.shutdown();
    assert_eq!(snap.retries, 1, "only the live rider re-enters the ring");
    assert_eq!(snap.deadline_shed, 1, "the expired rider is a deadline shed, not an error");
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.drain_shed, 0);
}

#[test]
fn drain_reclassifies_failover_as_closed() {
    // regression: a request failing over DURING a graceful drain used
    // to be answered `Worker("shard worker exited")` and counted in
    // `errors` — a drained request is not a worker fault.  With the
    // ring closed at failover time the rider must be answered `Closed`
    // and counted in `drain_shed`.
    let cache = ProgramCache::new();
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: Some(0),
        when: CallSel::Nth(0),
        action: FaultAction::SlowError(100_000), // outlives the drain deadline
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 2,
        batch_window_us: 100_000,
        queue_depth: 16,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    let rx_a = server.submit(image.clone()).expect("submit a");
    let rx_b = server.submit(image.clone()).expect("submit b");
    // let the worker consume the sealed frame and enter the 100ms burn
    std::thread::sleep(Duration::from_millis(10));
    let (snap, stats) = server.shutdown_with_deadline(Duration::from_millis(20));
    for (name, rx) in [("a", rx_a), ("b", rx_b)] {
        match rx.recv_timeout(Duration::from_secs(5)).unwrap_or_else(|_| panic!("{name} hung")) {
            Err(ServeError::Closed) => {}
            other => panic!("rider {name} must be drain-shed Closed, got {other:?}"),
        }
    }
    assert_eq!(stats.shed, 2, "both riders resolve as drain sheds");
    assert_eq!(snap.drain_shed, 2);
    assert_eq!(snap.completed, 0);
    assert_eq!(snap.errors, 0, "a drained request is not a worker error");
    assert_eq!(snap.retries, 0, "a closed ring accepts no failover re-queue");
}

#[test]
fn killed_core_fails_over_typed_and_the_cluster_keeps_serving() {
    // one worker serving through a 2-core cluster; core 1 is killed on
    // its FIRST core execution (per-core FaultRule targeting — the
    // rule's `worker` field addresses the core id in the core plan).
    // Its shard's riders fail over through the ring and come back Ok
    // off the surviving core; the worker itself stays alive.
    let cache = ProgramCache::new();
    let core_plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: Some(1),
        when: CallSel::Nth(0),
        action: FaultAction::Kill,
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 2,
        batch_window_us: 100_000,
        queue_depth: 16,
        cores: 2,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos_cores(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        None,
        Some(core_plan),
    )
    .unwrap();
    assert_eq!(server.cores(), 2);
    let image = vec![1.0; server.image_len()];
    // both riders land in one batch-2 frame (the second write seals
    // it); the frame shards across both cores, so core 1 executes —
    // and dies — deterministically.  Its rider must fail over Ok.
    let rx_a = server.submit(image.clone()).expect("submit a");
    let rx_b = server.submit(image.clone()).expect("submit b");
    for (name, rx) in [("a", rx_a), ("b", rx_b)] {
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap_or_else(|_| panic!("{name} hung"));
        assert!(r.is_ok(), "rider {name} must survive the core kill via failover: {r:?}");
    }
    let h = server.health();
    assert_eq!(h.alive, 1, "the worker survives its core's death");
    assert_eq!(h.cores_alive, 1, "the killed core stays dead");
    assert!(!h.cores[1].alive);
    assert!(h.cores[0].alive);
    assert!(h.cores[1].failures >= 1);
    // the surviving core keeps serving
    for i in 0..4 {
        let rx = server.submit(image.clone()).expect("submit");
        let r = rx.recv_timeout(Duration::from_secs(10)).expect("request hung");
        assert!(r.is_ok(), "request {i} must serve on the surviving core: {r:?}");
    }
    let snap = server.shutdown();
    assert!(snap.retries >= 1, "the killed shard's rider must have failed over");
    assert_eq!(snap.errors, 0, "failover hid the core kill from every client");
    assert!(snap.core_failures >= 1, "the kill is counted as a core failure");
}

#[test]
fn persistent_core_errors_surface_typed_after_one_failover() {
    // every core execution fails typed: a rider fails over once, fails
    // again, and the SECOND failure must reach the client as a typed
    // Worker error carrying the injected message — bounded, no hang.
    let cache = ProgramCache::new();
    let core_plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::Always,
        action: FaultAction::Error,
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 1,
        batch_window_us: 50,
        queue_depth: 16,
        cores: 2,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos_cores(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        None,
        Some(core_plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    for i in 0..3 {
        let rx = server.submit(image.clone()).expect("submit");
        match rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} hung"))
        {
            Err(ServeError::Worker(msg)) => {
                assert!(msg.contains("injected error"), "request {i}: {msg}")
            }
            other => panic!("request {i} must surface the core error typed, got {other:?}"),
        }
    }
    let h = server.health();
    assert_eq!(h.cores_alive, 2, "typed errors do not kill cores");
    assert_eq!(h.alive, 1, "typed core errors do not kill the worker either");
    let snap = server.shutdown();
    assert_eq!(snap.retries, 3, "every request fails over exactly once before surfacing");
    assert_eq!(snap.errors, 3, "every request surfaces exactly one typed error");
    assert!(snap.core_failures >= 6, "both attempts of every request failed a core");
}

#[test]
fn drain_under_load_resolves_every_request() {
    let cache = ProgramCache::new();
    // 5ms of injected delay per batch makes the backlog outlast the
    // drain deadline deterministically
    let plan = Arc::new(FaultPlan::from_rules(vec![FaultRule {
        worker: None,
        when: CallSel::Always,
        action: FaultAction::Delay(5_000),
    }]));
    let serve = ServeConfig {
        workers: 1,
        batch: 4,
        batch_window_us: 100,
        queue_depth: 64,
        ..ServeConfig::default()
    };
    let server = QnnBatchServer::start_chaos(
        ProcessorConfig::sparq(),
        &QnnGraph::sparq_cnn(),
        w2a2(),
        7,
        serve,
        &cache,
        Some(plan),
    )
    .unwrap();
    let image = vec![1.0; server.image_len()];
    let pending: Vec<_> = (0..30).map(|_| server.submit(image.clone()).expect("submit")).collect();
    let (snap, stats) = server.shutdown_with_deadline(Duration::from_millis(20));
    assert_eq!(
        stats.completed + stats.shed,
        30,
        "every request resolves exactly one way: executed or shed ({stats:?})"
    );
    assert!(stats.shed > 0, "a 20ms drain cannot clear 30 delayed requests");
    assert!(stats.completed >= 1, "work in flight at drain start still completes");
    assert_eq!(snap.drain_shed, stats.shed);
    for rx in pending {
        match rx.recv_timeout(Duration::from_secs(5)).expect("drained request hung") {
            Ok(_) | Err(ServeError::Closed) => {}
            other => panic!("expected Ok or Closed, got {other:?}"),
        }
    }
}
