//! Integration: the serving coordinator end-to-end over the real PJRT
//! executor — batched requests, accuracy, metrics, and failure modes.
//! Skips when artifacts are missing.

use sparq::config::ServeConfig;
use sparq::coordinator::{Executor, PjrtExecutor, ServeError, Server};
use sparq::runtime::{artifacts_dir, artifacts_present, TestSet};

fn start_server(model: &'static str, cfg: ServeConfig) -> Server {
    let dir = artifacts_dir();
    Server::start(
        Box::new(move || {
            Ok(Box::new(PjrtExecutor::new(&dir, model)?) as Box<dyn Executor>)
        }),
        cfg,
        42,
    )
    .expect("server")
}

#[test]
fn serves_the_testset_accurately() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let ts = TestSet::load(artifacts_dir().join("testset.bin")).expect("testset");
    let server = start_server(
        "qnn_w4a4",
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 128, ..Default::default() },
    );
    let n = 128.min(ts.n);
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push((i, server.submit(ts.image(i).to_vec()).expect("submit")));
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.logits.len(), 4);
        assert_eq!(r.sim_cycles, 42);
        correct += (r.class == ts.labels[i] as usize) as usize;
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "served accuracy {acc}");
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, n);
    assert!(snap.mean_batch >= 1.0);
}

#[test]
fn bad_model_name_fails_fast() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // every worker's init fails: start must refuse typed instead of
    // handing out a server whose queue nothing will ever drain
    let dir = artifacts_dir();
    let r = Server::start(
        Box::new(move || {
            Ok(Box::new(PjrtExecutor::new(&dir, "qnn_nonexistent")?) as Box<dyn Executor>)
        }),
        ServeConfig::default(),
        42,
    );
    match r {
        Err(ServeError::NoWorkers) => {}
        Ok(_) => panic!("start must fail when no worker initialises"),
        Err(e) => panic!("expected NoWorkers, got {e:?}"),
    }
}

#[test]
fn short_image_is_rejected_typed() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let server = start_server("qnn_w3a3", ServeConfig::default());
    // 10 < 256 floats: refused at submit — never silently zero-padded
    match server.infer(vec![0.5; 10]) {
        Err(ServeError::BadInput { got: 10, want }) => assert_eq!(want, 256),
        other => panic!("expected BadInput, got {other:?}"),
    }
    let snap = server.shutdown();
    assert_eq!(snap.bad_input, 1);
}
