//! Integration: the serving coordinator end-to-end over the real PJRT
//! executor — batched requests, accuracy, metrics, and failure modes.
//! Skips when artifacts are missing.

use sparq::config::ServeConfig;
use sparq::coordinator::{Executor, PjrtExecutor, ServeError, Server};
use sparq::runtime::{artifacts_dir, artifacts_present, TestSet};

fn start_server(model: &'static str, cfg: ServeConfig) -> Server {
    let dir = artifacts_dir();
    Server::start(
        Box::new(move || {
            Ok(Box::new(PjrtExecutor::new(&dir, model)?) as Box<dyn Executor>)
        }),
        cfg,
        42,
    )
    .expect("server")
}

#[test]
fn serves_the_testset_accurately() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let ts = TestSet::load(artifacts_dir().join("testset.bin")).expect("testset");
    let server = start_server(
        "qnn_w4a4",
        ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 128, ..Default::default() },
    );
    let n = 128.min(ts.n);
    let mut pending = Vec::new();
    for i in 0..n {
        pending.push((i, server.submit(ts.image(i).to_vec()).expect("submit")));
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let r = rx.recv().unwrap().unwrap();
        assert_eq!(r.logits.len(), 4);
        assert_eq!(r.sim_cycles, 42);
        correct += (r.class == ts.labels[i] as usize) as usize;
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "served accuracy {acc}");
    let snap = server.shutdown();
    assert_eq!(snap.completed as usize, n);
    assert!(snap.mean_batch >= 1.0);
}

#[test]
fn bad_model_name_fails_fast() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let server = start_server("qnn_nonexistent", ServeConfig::default());
    // the worker dies during init; requests must not hang forever
    match server.submit(vec![0.0; 256]) {
        Ok(rx) => {
            // channel closes when the worker exits
            let r = rx.recv_timeout(std::time::Duration::from_secs(30));
            assert!(matches!(r, Err(_) | Ok(Err(ServeError::Worker(_)))));
        }
        Err(_) => {} // also acceptable: queue rejected
    }
    server.shutdown();
}

#[test]
fn short_image_is_zero_padded_not_crashing() {
    if !artifacts_present() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let server = start_server("qnn_w3a3", ServeConfig::default());
    let r = server.infer(vec![0.5; 10]).expect("infer"); // 10 < 256 floats
    assert_eq!(r.logits.len(), 4);
    server.shutdown();
}
