//! Cache correctness: a cached `Program` re-executed on rebound
//! tensors must be bit-identical — conv outputs *and* `RunReport`
//! cycle counts — to a cold build, across Int16 / Native / Vmacsr
//! variants and both `RegionMode`s.  This is the contract that makes
//! compile-once/execute-many serving sound.

use sparq::arch::ProcessorConfig;
use sparq::kernels::workload::golden_exact;
use sparq::kernels::{
    compile_conv, run_conv, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload,
};
use sparq::sim::{Machine, MachinePool};
use sparq::ulppack::RegionMode;

fn dims() -> ConvDims {
    ConvDims { c: 8, h: 10, w: 40, co: 2, fh: 3, fw: 3 }
}

/// Every (variant, processor) pair the matrix covers: both containers
/// (ULP via W2A2, LP via W3A3/W4A4) and both region modes.
fn matrix() -> Vec<(ConvVariant, ProcessorConfig)> {
    let sparq = ProcessorConfig::sparq;
    let ara = ProcessorConfig::ara;
    vec![
        (ConvVariant::Int16, sparq()),
        (ConvVariant::Native { w_bits: 2, a_bits: 2 }, ara()),
        (ConvVariant::Native { w_bits: 1, a_bits: 1 }, ara()),
        (ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict }, sparq()),
        (ConvVariant::Vmacsr { w_bits: 3, a_bits: 3, mode: RegionMode::Strict }, sparq()),
        (ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper }, sparq()),
        (ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Paper }, sparq()),
    ]
}

#[test]
fn cached_execution_bit_identical_to_cold_build() {
    let cache = ProgramCache::new();
    let pool = MachinePool::new();
    for (variant, cfg) in matrix() {
        let (wb, ab) = variant.bits();
        let wl = Workload::random(dims(), wb, ab, 0xCAFE);

        // cold: the seed's rebuild-every-call path
        let cold = run_conv(&cfg, &wl, variant).unwrap();
        let cold_out = cold.out.read_ints(&cold.machine.mem).unwrap();

        // warm: cached program on a pooled, reset-in-place machine — 3x
        for rep in 0..3 {
            let cc = cache.get_or_compile(&cfg, &wl, variant, EngineOpts::default()).unwrap();
            let mut m = pool.acquire(&cfg, cc.mem_bytes);
            let report = cc.execute(&mut m, &wl).unwrap();
            let out = cc.out.read_ints(&m.mem).unwrap();
            pool.release(m);
            assert_eq!(out, cold_out, "{variant:?} rep {rep}: outputs diverged");
            assert_eq!(
                report.stats.cycles,
                cold.report.stats.cycles,
                "{variant:?} rep {rep}: cycle counts diverged"
            );
            assert_eq!(report.label, cold.report.label, "{variant:?}: labels diverged");
            assert_eq!(report.macs, cold.report.macs);
        }
    }
    let s = cache.stats();
    assert_eq!(s.misses as usize, matrix().len(), "each variant compiles exactly once");
    assert_eq!(s.hits as usize, 2 * matrix().len());
    assert!(pool.stats().reused > 0, "pool never reused a machine");
}

#[test]
fn rebinding_fresh_activations_matches_a_fresh_build() {
    // the serving scenario: weights frozen at compile time, activations
    // changing per request
    let cfg = ProcessorConfig::sparq();
    let variant = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
    let wl = Workload::random(dims(), 2, 2, 0xBEEF);
    let cc = compile_conv(&cfg, &wl, variant).unwrap();

    // a second workload: same weights, different activations
    let mut wl2 = wl.clone();
    for row in wl2.act.iter_mut() {
        for v in row.iter_mut() {
            *v = (*v + 1) % 4; // stay in the A2 level range
        }
    }

    let mut m = Machine::new(cfg.clone(), wl2.mem_bytes());
    let report = cc.execute(&mut m, &wl2).unwrap();
    let out = cc.out.read_ints(&m.mem).unwrap();

    // reference: a cold build on wl2 (same weights -> same program)
    let fresh = run_conv(&cfg, &wl2, variant).unwrap();
    assert_eq!(out, fresh.out.read_ints(&fresh.machine.mem).unwrap());
    assert_eq!(report.stats.cycles, fresh.report.stats.cycles);
    // and the strict-region kernel is still exact on the new data
    assert_eq!(out, golden_exact(&wl2));
}

#[test]
fn offline_packing_opts_cached_too() {
    // both RegionModes x both packing modes through the cache
    let cfg = ProcessorConfig::sparq();
    for mode in [RegionMode::Strict, RegionMode::Paper] {
        let variant = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode };
        let wl = Workload::random(dims(), 2, 2, 0xD00D);
        for opts in [
            EngineOpts::default(),
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        ] {
            let cache = ProgramCache::new();
            let pool = MachinePool::new();
            let cold = sparq::kernels::run_conv_opts(&cfg, &wl, variant, opts).unwrap();
            let rep =
                sparq::kernels::run_conv_cached(&cache, &pool, &cfg, &wl, variant, opts).unwrap();
            assert_eq!(rep.stats.cycles, cold.report.stats.cycles, "{mode:?} {opts:?}");
        }
    }
}

#[test]
fn execute_rejects_mismatched_machine_or_workload() {
    let cfg = ProcessorConfig::sparq();
    let variant = ConvVariant::Int16;
    let wl = Workload::random(dims(), 8, 8, 0xF00);
    let cc = compile_conv(&cfg, &wl, variant).unwrap();

    // wrong processor config
    let mut wrong_m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
    assert!(cc.execute(&mut wrong_m, &wl).is_err());

    // wrong workload shape
    let small = Workload::random(ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 }, 8, 8, 1);
    let mut m = Machine::new(cfg.clone(), wl.mem_bytes());
    assert!(cc.execute(&mut m, &small).is_err());

    // right inputs still fine on the same machine afterwards
    assert!(cc.execute(&mut m, &wl).is_ok());
}

#[test]
fn per_layer_precision_overrides_occupy_distinct_cache_entries() {
    // two graphs identical except one layer's (w_bits, a_bits): both
    // the key objects and the live cache entries must stay apart
    use sparq::qnn::schedule::QnnPrecision;
    use sparq::qnn::QnnGraph;
    let cfg = ProcessorConfig::sparq();
    let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let plain = QnnGraph::sparq_cnn();
    let mixed = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
    assert_ne!(
        ProgramCache::qnn_key(&cfg, &plain, prec, 7),
        ProgramCache::qnn_key(&cfg, &mixed, prec, 7)
    );

    let cache = ProgramCache::new();
    let a = cache.get_or_compile_qnn(&cfg, &plain, prec, 7).unwrap();
    let b = cache.get_or_compile_qnn(&cfg, &mixed, prec, 7).unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &b), "override graphs must not share an entry");
    let s = cache.stats();
    assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2));
    // the overridden layer's tuning is its own memo entry too: the
    // graphs share the stem and the W2A2 deep conv shapes, but the
    // W4A4 stem-adjacent layer adds a fourth (cfg, shape, precision)
    assert_eq!(s.tune_entries, 4, "stem + w2a2@16x16 + w2a2@8x8 + w4a4@16x16");
    assert_eq!(s.tune_misses, 4);
    assert_eq!(s.tune_hits, 2, "shared shapes must hit the tune memo across graphs");
}

#[test]
fn compiled_program_is_machine_free_and_reusable_across_machines() {
    let cfg = ProcessorConfig::sparq();
    let variant = ConvVariant::Vmacsr { w_bits: 3, a_bits: 3, mode: RegionMode::Strict };
    let wl = Workload::random(dims(), 3, 3, 0xABC);
    let cc = compile_conv(&cfg, &wl, variant).unwrap();
    let golden = golden_exact(&wl);
    let mut outs = Vec::new();
    for _ in 0..2 {
        let mut m = Machine::new(cfg.clone(), wl.mem_bytes());
        cc.execute(&mut m, &wl).unwrap();
        outs.push(cc.out.read_ints(&m.mem).unwrap());
    }
    assert_eq!(outs[0], outs[1]);
    assert_eq!(outs[0], golden);
}
