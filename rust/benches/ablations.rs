//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. runtime vs offline operand packing (the paper notes weight
//!    packing "could be avoided by offline preprocessing")
//! 2. lane-count scaling (1/2/4/8 lanes)
//! 3. the future-work configurable shifter: vmacsr.cfg lets ULP use a
//!    k=2-but-asymmetric layout — modelled here as identical cost
//! 4. vmacsr without FPU removal (does the speedup need the area cut?)
//! 5. spill-cadence sensitivity (strict vs paper admission at W4A4)

mod common;

use common::Bench;
use sparq::arch::ProcessorConfig;
use sparq::kernels::{run_conv, run_conv_cached, ConvDims, ConvVariant, EngineOpts, Workload};
use sparq::report::SweepCtx;
use sparq::ulppack::RegionMode;

fn main() {
    let b = Bench::new("ablations");
    let dims = ConvDims::fig4(false);
    let sparq = ProcessorConfig::sparq();
    // one compile-once context across every section: the (sparq, W2A2)
    // point recurs in sections 1 and 4 and compiles exactly once
    let ctx = SweepCtx::new();

    // 1. packing: runtime vs offline
    b.section("packing ablation", || {
        let wl = Workload::random(dims, 2, 2, 5);
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
        let rt = ctx.run(&sparq, &wl, v).unwrap();
        let off = run_conv_cached(
            &ctx.cache,
            &ctx.pool,
            &sparq,
            &wl,
            v,
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        )
        .unwrap();
        println!(
            "  runtime packing: {} cycles | offline: {} cycles | overhead {:.1}%",
            rt.stats.cycles,
            off.stats.cycles,
            100.0 * (rt.stats.cycles as f64 / off.stats.cycles as f64 - 1.0)
        );
    });

    // 2. lane scaling
    b.section("lane scaling", || {
        for lanes in [1u32, 2, 4, 8] {
            let cfg = ProcessorConfig::sparq().with_lanes(lanes);
            let wl = Workload::random(dims, 2, 2, 5);
            let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
            let r = ctx.run(&cfg, &wl, v).unwrap();
            println!(
                "  {lanes} lane(s): {:>10} cycles, {:>6.2} ops/cycle",
                r.stats.cycles,
                r.ops_per_cycle()
            );
        }
    });

    // 3. configurable shifter: custom shift for asymmetric fields
    b.section("configurable shifter (future work)", || {
        use sparq::isa::{Lmul, Sew, VInst, VOp};
        use sparq::sim::{Machine, Program};
        let mut m = Machine::new(ProcessorConfig::sparq_cfgshift(), 1 << 16);
        m.set_shift_csr(6); // asymmetric 10/6 split instead of 8/8
        let mut p = Program::new("vmacsr.cfg");
        p.push(VInst::SetVl { avl: 64, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::MacsrCfg, vd: 1, vs2: 2, rs1: 3 });
        let r = m.run(&p).unwrap();
        println!(
            "  vmacsr.cfg executes with CSR shift=6: {} cycles (same datapath cost as vmacsr)",
            r.stats.cycles
        );
        // and it traps on plain sparq
        let mut m2 = Machine::new(ProcessorConfig::sparq(), 1 << 16);
        let err = m2.run(&p).unwrap_err();
        println!("  on plain Sparq: {err}");
    });

    // 4. vmacsr with the FPU kept (area/power cost, same cycles)
    b.section("vmacsr without FPU removal", || {
        let mut cfg = ProcessorConfig::ara();
        cfg.vmacsr = true;
        cfg.name = "ara+vmacsr".into();
        let wl = Workload::random(dims, 2, 2, 5);
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
        let with_fpu = ctx.run(&cfg, &wl, v).unwrap();
        let without = ctx.run(&sparq, &wl, v).unwrap();
        let pw = sparq::power::LaneReport::for_config(&cfg);
        let ps = sparq::power::LaneReport::for_config(&sparq);
        println!(
            "  cycles identical: {} vs {} | lane power {:.1} vs {:.1} mW | ops/nJ {:.2} vs {:.2}",
            with_fpu.stats.cycles,
            without.stats.cycles,
            pw.power_mw(),
            ps.power_mw(),
            pw.ops_per_nj(with_fpu.ops_per_cycle()),
            ps.ops_per_nj(without.ops_per_cycle())
        );
    });

    // 5b. direct conv vs im2col+GEMM (the §III-A design argument)
    b.section("direct vs im2col+GEMM", || {
        use sparq::sim::Machine;
        let d = ConvDims { c: 16, h: 26, w: 70, co: 4, fh: 7, fw: 7 };
        let wl = Workload::random(d, 2, 2, 5);
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let direct = run_conv(&sparq, &wl, v).unwrap().report;
        let mut m = Machine::new(sparq.clone(), wl.mem_bytes() * 8);
        let (prog, _) =
            sparq::kernels::im2col_gemm::build(&mut m, &wl, 2, 2, RegionMode::Strict).unwrap();
        let gemm = m.run(&prog).unwrap();
        let mb = |r: &sparq::sim::RunReport| r.stats.bytes_loaded + r.stats.bytes_stored;
        println!(
            "  direct: {} cycles, {:.2} MB moved | im2col+GEMM: {} cycles, {:.2} MB moved ({:.1}x traffic)",
            direct.stats.cycles,
            mb(&direct) as f64 / 1e6,
            gemm.stats.cycles,
            mb(&gemm) as f64 / 1e6,
            mb(&gemm) as f64 / mb(&direct) as f64
        );
    });

    // 5. admission-mode sensitivity at W4A4
    b.section("region mode at W4A4", || {
        let wl = Workload::random(dims, 4, 4, 5);
        let paper = ctx
            .run(&sparq, &wl, ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Paper })
            .unwrap();
        println!(
            "  paper-mode LP: {} cycles ({:.2} ops/cycle); strict mode refuses W4A4 (dot field 420 > 255)",
            paper.stats.cycles,
            paper.ops_per_cycle()
        );
        let strict = run_conv(
            &sparq,
            &wl,
            ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Strict },
        );
        assert!(strict.is_err());
    });

    let cs = ctx.cache.stats();
    println!(
        "\nprogram cache across sections: {} compiles, {} hits (the shared W2A2 point compiled once)",
        cs.misses, cs.hits
    );
    b.finish();
}
