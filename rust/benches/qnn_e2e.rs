//! End-to-end network bench: the whole SparqCNN as one chained
//! dataflow program (compile once, infer many).  Reports per-layer
//! cycles, images/s at the modelled fmax, host-side inference
//! throughput, and the program-cache hit rate across repeated
//! inferences.  `--json` writes `BENCH_qnn.json` next to
//! `BENCH_simspeed.json` (CI uploads both).

mod common;

use common::{json_flag, Bench, Json};
use sparq::kernels::ProgramCache;
use sparq::power::LaneReport;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::QnnGraph;
use sparq::runtime::SimQnnModel;
use sparq::sim::MachinePool;
use sparq::ProcessorConfig;
use std::time::Instant;

const SEED: u64 = 0xBE7C_5EED;
const REPS: usize = 24;

fn main() {
    let b = Bench::new("qnn_e2e");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let graph = QnnGraph::sparq_cnn();
    let cache = ProgramCache::new();
    let mut json = Json::new();
    json.str("bench", "qnn_e2e").int("reps", REPS as u64).num("fmax_ghz", fmax);

    let mut precisions = Vec::new();
    for prec in [
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        QnnPrecision::SubByte { w_bits: 3, a_bits: 3 },
        QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
    ] {
        let label = prec.label();
        let (sched, layer_rows, cycles, host_s) = b.section(&label, || {
            let pool = MachinePool::new();
            let t0 = Instant::now();
            let sched = sparq::qnn::schedule::schedule_seeded(
                &cfg, &graph, prec, SEED, &cache, &pool,
            )
            .expect("schedule");
            let compile_s = t0.elapsed().as_secs_f64();
            let model =
                SimQnnModel::compile(&cfg, &graph, prec, SEED, &cache).expect("model");

            // repeated inferences through the cached network
            let images: Vec<Vec<f32>> = (0..REPS)
                .map(|i| {
                    model
                        .cq
                        .net
                        .test_image(i as u64)
                        .iter()
                        .map(|&v| v as f32)
                        .collect()
                })
                .collect();
            let t1 = Instant::now();
            let mut cycles_each = Vec::with_capacity(REPS);
            for img in &images {
                let (_logits, cyc) = model.infer(&pool, img).expect("infer");
                cycles_each.push(cyc);
            }
            let infer_s = t1.elapsed().as_secs_f64();
            assert!(
                cycles_each.iter().all(|&c| c == cycles_each[0]),
                "cycle counts must be identical across repeated inferences"
            );
            println!(
                "  {label}: {} cycles/image -> {:.0} img/s at {fmax:.3} GHz | host: compile {compile_s:.3}s, {REPS} inferences in {infer_s:.3}s ({:.1} inf/s)",
                cycles_each[0],
                fmax * 1e9 / cycles_each[0] as f64,
                REPS as f64 / infer_s
            );
            for l in &sched.layers {
                println!("    {:<26} {:>12} cycles  {}", l.name, l.cycles, l.variant);
            }
            // index-prefixed: two maxpool layers must not collide as
            // JSON keys
            let rows: Vec<(String, u64, String)> = sched
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| (format!("L{i} {}", l.name), l.cycles, l.variant.clone()))
                .collect();
            (sched, rows, cycles_each[0], infer_s)
        });
        precisions.push((label, sched, layer_rows, cycles, host_s));
    }

    let cs = cache.stats();
    let total_lookups = cs.hits + cs.misses;
    println!(
        "program cache: {} network compile(s), {} hits ({} lookups, {:.1}% hit rate)",
        cs.misses,
        cs.hits,
        total_lookups,
        100.0 * cs.hits as f64 / total_lookups.max(1) as f64
    );

    if json_flag() {
        json.obj("precisions", |j| {
            for (label, sched, rows, cycles, host_s) in &precisions {
                j.obj(label, |j| {
                    j.int("cycles_per_image", *cycles)
                        .num("images_per_s_at_fmax", fmax * 1e9 / *cycles as f64)
                        .num("host_infer_s", *host_s)
                        .num("host_inferences_per_s", REPS as f64 / *host_s)
                        .int("total_macs", sched.total_macs())
                        .obj("layers", |j| {
                            for (name, cyc, variant) in rows {
                                j.obj(name, |j| {
                                    j.int("cycles", *cyc).str("variant", variant);
                                });
                            }
                        });
                });
            }
        });
        json.obj("cache", |j| {
            j.int("compiles", cs.misses).int("hits", cs.hits).num(
                "hit_rate",
                cs.hits as f64 / total_lookups.max(1) as f64,
            );
        });
        json.write("BENCH_qnn.json");
    }

    b.finish();
}
