//! Graph-topology bench: the chain (SparqCNN), residual, depthwise
//! and dense-head networks, each compiled as ONE cached dataflow
//! program over the liveness-planned arena, at W2A2 and W4A4.
//! Reports cycles/image, images/s at the modelled fmax, and the arena
//! footprint (per-image slot bytes) against the pre-liveness
//! append-only layout.  `--json` writes `BENCH_topo.json` next to the
//! other BENCH files; CI smoke-runs and uploads it, and
//! `sparq bench-check` gates the cycle fields once the baseline in
//! `ci/bench_baselines/BENCH_topo.json` is blessed.
//!
//! Asserted invariants (the PR's acceptance shape):
//! - per-topology cycle counts are identical across repeated
//!   inferences AND across the liveness / append-only layouts (timing
//!   is address-independent — reuse can only shrink the arena);
//! - the liveness arena is never larger than append-only, and is
//!   STRICTLY smaller on the residual network (the join keeps two
//!   branches live, then both die and their ranges recycle).

mod common;

use common::{json_flag, Bench, Json};
use sparq::kernels::ProgramCache;
use sparq::power::LaneReport;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::{CompiledQnn, QnnGraph, QnnNet};
use sparq::runtime::SimQnnModel;
use sparq::sim::MachinePool;
use sparq::ProcessorConfig;

const SEED: u64 = 0x7090_5EED;
const REPS: usize = 8;

fn topologies() -> Vec<(&'static str, QnnGraph)> {
    vec![
        ("chain", QnnGraph::sparq_cnn()),
        ("resnetlike", QnnGraph::sparq_resnetlike()),
        ("mobilenetlike", QnnGraph::sparq_mobilenetlike()),
        ("denselike", QnnGraph::sparq_denselike()),
    ]
}

struct Row {
    label: String,
    cycles: u64,
    layers: usize,
    live_bytes: u64,
    append_bytes: u64,
}

fn main() {
    let b = Bench::new("topologies");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let cache = ProgramCache::new();
    let pool = MachinePool::new();
    let mut json = Json::new();
    json.str("bench", "topologies").int("reps", REPS as u64).num("fmax_ghz", fmax);

    let mut rows: Vec<Row> = Vec::new();
    for prec in [
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
    ] {
        for (topo, graph) in topologies() {
            let label = format!("{topo} {}", prec.label());
            let row = b.section(&label, || {
                let model =
                    SimQnnModel::compile(&cfg, &graph, prec, SEED, &cache).expect("model");
                let image: Vec<f32> =
                    (0..model.input_len()).map(|i| ((i * 7) % 4) as f32).collect();
                let mut cycles_each = Vec::with_capacity(REPS);
                for _ in 0..REPS {
                    let (_, cyc) = model.infer(&pool, &image).expect("infer");
                    cycles_each.push(cyc);
                }
                assert!(
                    cycles_each.iter().all(|&c| c == cycles_each[0]),
                    "{label}: cycle counts must be identical across repeated inferences"
                );

                // the pre-liveness layout: same streams, fresh offsets
                // everywhere — cycles must match exactly, only the
                // arena high-water mark may differ
                let net = QnnNet::from_seed(&graph, prec, SEED).expect("net");
                let ao = CompiledQnn::compile_append_only(&cfg, net, &cache).expect("ao");
                let image_lv = model.cq.net.test_image(0);
                let mut m = sparq::sim::Machine::new(cfg.clone(), ao.mem_bytes);
                let ao_run = ao.execute(&mut m, &image_lv).expect("ao execute");
                assert_eq!(
                    ao_run.total_cycles(),
                    {
                        let mut m2 =
                            sparq::sim::Machine::new(cfg.clone(), model.cq.mem_bytes);
                        model.cq.execute(&mut m2, &image_lv).expect("live execute").total_cycles()
                    },
                    "{label}: liveness placement must not change cycle counts"
                );
                assert!(
                    model.cq.slot_stride <= ao.slot_stride,
                    "{label}: liveness arena grew past append-only"
                );
                if topo == "resnetlike" {
                    assert!(
                        model.cq.slot_stride < ao.slot_stride,
                        "{label}: liveness must strictly shrink the residual arena"
                    );
                }

                println!(
                    "  {label}: {} cycles/image -> {:.0} img/s at {fmax:.3} GHz | arena {} B/slot (append-only {} B, {:.1}% saved)",
                    cycles_each[0],
                    fmax * 1e9 / cycles_each[0] as f64,
                    model.cq.slot_stride,
                    ao.slot_stride,
                    100.0 * (1.0 - model.cq.slot_stride as f64 / ao.slot_stride as f64),
                );
                Row {
                    label: label.clone(),
                    cycles: cycles_each[0],
                    layers: model.cq.taps.len(),
                    live_bytes: model.cq.slot_stride,
                    append_bytes: ao.slot_stride,
                }
            });
            rows.push(row);
        }
    }

    let cs = cache.stats();
    println!(
        "program cache: {} network compile(s), {} hits | autotune: {} measurement(s), {} memo hits",
        cs.misses, cs.hits, cs.tune_misses, cs.tune_hits
    );

    if json_flag() {
        json.obj("topologies", |j| {
            for r in &rows {
                j.obj(&r.label, |j| {
                    j.int("cycles_per_image", r.cycles)
                        .num("images_per_s_at_fmax", fmax * 1e9 / r.cycles as f64)
                        .int("layer_count", r.layers as u64)
                        .int("arena_slot_bytes", r.live_bytes)
                        .int("arena_slot_bytes_append_only", r.append_bytes)
                        .num(
                            "arena_savings_frac",
                            1.0 - r.live_bytes as f64 / r.append_bytes as f64,
                        );
                });
            }
        });
        json.obj("cache", |j| {
            j.int("compiles", cs.misses)
                .int("hits", cs.hits)
                .int("tune_measurements", cs.tune_misses)
                .int("tune_hits", cs.tune_hits);
        });
        json.write("BENCH_topo.json");
    }

    b.finish();
}
