//! Shared mini-harness for the paper-reproduction benches (the vendored
//! crate set has no criterion; this provides the timing/reporting
//! conventions: named sections, wall-clock, and a stable output format
//! that `bench_output.txt` captures).
#![allow(dead_code)] // each bench uses a different subset of the harness

use std::time::Instant;

pub struct Bench {
    name: &'static str,
    t0: Instant,
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        println!("\n================ bench: {name} ================");
        Bench { name, t0: Instant::now() }
    }

    /// Time one section; prints its wall time and returns the value.
    pub fn section<T>(&self, label: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let v = f();
        println!("[{} / {label}] {:.3}s", self.name, t.elapsed().as_secs_f64());
        v
    }

    pub fn finish(self) {
        println!(
            "================ bench: {} done in {:.3}s ================",
            self.name,
            self.t0.elapsed().as_secs_f64()
        );
    }
}

/// `--large` flag passthrough (cargo bench -- --large).
pub fn large_flag() -> bool {
    std::env::args().any(|a| a == "--large")
}

/// `--json` flag passthrough (cargo bench -- --json): also write the
/// bench's numbers to a `BENCH_<name>.json` artifact so the perf
/// trajectory is machine-trackable across PRs (CI uploads it).
pub fn json_flag() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Minimal hand-rolled JSON object writer — the crate set is
/// dependency-free, and the benches only need flat/nested objects of
/// numbers and strings.
pub struct Json {
    buf: String,
    first: bool,
}

impl Default for Json {
    fn default() -> Json {
        Json::new()
    }
}

impl Json {
    pub fn new() -> Json {
        Json { buf: String::from("{"), first: true }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('"');
        self.buf.push_str(&escape(k));
        self.buf.push_str("\":");
    }

    pub fn num(&mut self, k: &str, v: f64) -> &mut Json {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn int(&mut self, k: &str, v: u64) -> &mut Json {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn str(&mut self, k: &str, v: &str) -> &mut Json {
        self.key(k);
        self.buf.push('"');
        self.buf.push_str(&escape(v));
        self.buf.push('"');
        self
    }

    /// Nested object: `j.obj("inner", |j| { j.int("x", 1); })`.
    pub fn obj(&mut self, k: &str, f: impl FnOnce(&mut Json)) -> &mut Json {
        self.key(k);
        let mut inner = Json::new();
        f(&mut inner);
        self.buf.push_str(&inner.finish());
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }

    /// Serialize and write to `path` (panics on IO errors — bench-only).
    pub fn write(self, path: &str) {
        let s = self.finish();
        std::fs::write(path, &s).unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("wrote {path} ({} bytes)", s.len());
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
