//! Shared mini-harness for the paper-reproduction benches (the vendored
//! crate set has no criterion; this provides the timing/reporting
//! conventions: named sections, wall-clock, and a stable output format
//! that `bench_output.txt` captures).
#![allow(dead_code)] // each bench uses a different subset of the harness

use std::time::Instant;

pub struct Bench {
    name: &'static str,
    t0: Instant,
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        println!("\n================ bench: {name} ================");
        Bench { name, t0: Instant::now() }
    }

    /// Time one section; prints its wall time and returns the value.
    pub fn section<T>(&self, label: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let v = f();
        println!("[{} / {label}] {:.3}s", self.name, t.elapsed().as_secs_f64());
        v
    }

    pub fn finish(self) {
        println!(
            "================ bench: {} done in {:.3}s ================",
            self.name,
            self.t0.elapsed().as_secs_f64()
        );
    }
}

/// `--large` flag passthrough (cargo bench -- --large).
pub fn large_flag() -> bool {
    std::env::args().any(|a| a == "--large")
}
