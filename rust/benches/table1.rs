//! Regenerates the paper's Table I (substituted per DESIGN.md §2):
//! accuracy of the trained SparqCNN at FP32 / W4A4 / W3A3 / W2A2,
//! evaluated through the PJRT-compiled artifacts on the held-out set.
//! Needs `make artifacts`.

mod common;

use common::Bench;
use sparq::report;
use sparq::runtime::{artifacts_dir, artifacts_present, Runtime, TestSet};

fn main() {
    let b = Bench::new("table1");
    if !artifacts_present() {
        println!("SKIP: no artifacts (run `make artifacts`)");
        b.finish();
        return;
    }
    let dir = artifacts_dir();
    let rt = b.section("load + compile artifacts", || Runtime::load(&dir).expect("runtime"));
    let ts = TestSet::load(dir.join("testset.bin")).expect("testset");
    let mut rows = Vec::new();
    let mut fp32 = 0.0;
    for name in ["qnn_fp32", "qnn_w4a4", "qnn_w3a3", "qnn_w2a2"] {
        let acc = b.section(name, || evaluate(&rt, name, &ts).expect(name));
        if name == "qnn_fp32" {
            fp32 = acc;
        }
        rows.push((name.trim_start_matches("qnn_").to_string(), acc, acc - fp32));
    }
    print!("{}", report::render_table1(&rows));
    println!(
        "paper check: sub-byte accuracy within 2% of fp32 -> {}",
        if rows.iter().all(|r| r.2 > -0.02) { "holds" } else { "VIOLATED" }
    );
    b.finish();
}

fn evaluate(rt: &Runtime, model: &str, ts: &TestSet) -> Result<f64, String> {
    let art = rt.manifest.artifact(model).ok_or("missing artifact")?;
    let batch = art.meta_u32("batch").unwrap_or(16) as usize;
    let dims = [batch as i64, ts.c as i64, ts.h as i64, ts.w as i64];
    let (mut correct, mut total, mut start) = (0usize, 0usize, 0usize);
    while start < ts.n {
        let (data, real) = ts.batch(start, batch);
        let logits = rt.exec_f32(model, &[(&data, &dims)]).map_err(|e| e.to_string())?;
        let classes = logits.len() / batch;
        for i in 0..real {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            correct += (pred == ts.labels[start + i] as usize) as usize;
            total += 1;
        }
        start += batch;
    }
    Ok(correct as f64 / total as f64)
}
