//! Mixed-precision ladder bench: the SparqCNN end-to-end at every
//! uniform sub-byte precision plus the mixed stem/head configurations,
//! each compiled with per-layer autotuned kernels, against the
//! all-int16 reference network (the paper's speedup denominator).
//! `--json` writes `BENCH_mixed.json` (per-layer variant choices,
//! network img/s, tune/cache stats; CI uploads it next to
//! `BENCH_qnn.json`).
//!
//! Asserted orderings (the paper's Fig. 4/5 shape at network scale):
//! autotuned W2A2 strictly fewer cycles than W4A4, and both strictly
//! fewer than all-int16.

mod common;

use common::{json_flag, Bench, Json};
use sparq::kernels::ProgramCache;
use sparq::power::LaneReport;
use sparq::qnn::schedule::QnnPrecision;
use sparq::qnn::{CompiledQnn, QnnGraph, QnnNet, VariantPolicy};
use sparq::report::ladder_configs;
use sparq::runtime::SimQnnModel;
use sparq::sim::{Machine, MachinePool};
use sparq::ProcessorConfig;

const SEED: u64 = 0x3153_5EED;
const REPS: usize = 12;

fn main() {
    let b = Bench::new("mixed_precision");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let cache = ProgramCache::new();
    let pool = MachinePool::new();
    let mut json = Json::new();
    json.str("bench", "mixed_precision").int("reps", REPS as u64).num("fmax_ghz", fmax);

    // the same rungs (and labels) report::precision_ladder sweeps
    let configs = ladder_configs();

    let mut rows = Vec::new();
    for (label, graph, prec) in &configs {
        let (cycles, layers) = b.section(label, || {
            let sched = sparq::qnn::schedule::schedule_seeded(
                &cfg, graph, *prec, SEED, &cache, &pool,
            )
            .expect("schedule");
            // repeat inference through the serving model: all-hits,
            // identical per-inference cycles
            let model = SimQnnModel::compile(&cfg, graph, *prec, SEED, &cache).expect("model");
            let img: Vec<f32> =
                (0..model.input_len()).map(|i| ((i * 13) % 4) as f32).collect();
            let mut cycles_each = Vec::with_capacity(REPS);
            for _ in 0..REPS {
                let (_, cyc) = model.infer(&pool, &img).expect("infer");
                cycles_each.push(cyc);
            }
            assert!(
                cycles_each.iter().all(|&c| c == cycles_each[0]),
                "cycle counts must be identical across repeated inferences"
            );
            println!(
                "  {label}: {} cycles/image -> {:.0} img/s at {fmax:.3} GHz",
                sched.total_cycles(),
                fmax * 1e9 / sched.total_cycles() as f64
            );
            let layer_rows: Vec<(String, u64, String)> = sched
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| (format!("L{i} {}", l.name), l.cycles, l.variant.clone()))
                .collect();
            for (name, lcyc, variant) in &layer_rows {
                println!("    {name:<30} {lcyc:>12} cycles  {variant}");
            }
            (sched.total_cycles(), layer_rows)
        });
        rows.push((label.clone(), cycles, layers));
    }

    // the all-int16 reference network: same W2A2 weights, every conv
    // forced onto the unpacked int16 kernel
    let base = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
    let int16_cycles = b.section("all-int16 reference", || {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), base, SEED).expect("net");
        let cq = CompiledQnn::compile_policy(&cfg, net, &cache, VariantPolicy::AllInt16)
            .expect("compile");
        let image = cq.net.test_image(1);
        let mut m = Machine::new(cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).expect("execute");
        println!("  all-int16: {} cycles/image", run.total_cycles());
        run.total_cycles()
    });

    let cyc = |label: &str| rows.iter().find(|r| r.0 == label).unwrap().1;
    // the acceptance ordering: autotuned W2A2 < W4A4 < all-int16
    assert!(
        cyc("w2a2") < cyc("w4a4"),
        "w2a2 ({}) must beat w4a4 ({})",
        cyc("w2a2"),
        cyc("w4a4")
    );
    assert!(
        cyc("w4a4") < int16_cycles,
        "w4a4 ({}) must beat all-int16 ({int16_cycles})",
        cyc("w4a4")
    );
    let mixed = cyc("mixed w4a4-stem/w2a2");
    assert!(
        cyc("w2a2") < mixed && mixed < cyc("w4a4"),
        "mixed ({mixed}) must land between w2a2 ({}) and w4a4 ({})",
        cyc("w2a2"),
        cyc("w4a4")
    );

    let cs = cache.stats();
    println!(
        "program cache: {} network compile(s), {} hits | autotune: {} measurement(s), {} memo hits",
        cs.misses, cs.hits, cs.tune_misses, cs.tune_hits
    );

    if json_flag() {
        json.obj("configs", |j| {
            for (label, cycles, layers) in &rows {
                j.obj(label, |j| {
                    j.int("cycles_per_image", *cycles)
                        .num("images_per_s_at_fmax", fmax * 1e9 / *cycles as f64)
                        .num("speedup_vs_int16", int16_cycles as f64 / *cycles as f64)
                        .obj("layers", |j| {
                            for (name, cyc, variant) in layers {
                                j.obj(name, |j| {
                                    j.int("cycles", *cyc).str("variant", variant);
                                });
                            }
                        });
                });
            }
        });
        json.int("int16_reference_cycles", int16_cycles);
        json.obj("cache", |j| {
            j.int("compiles", cs.misses)
                .int("hits", cs.hits)
                .int("tune_measurements", cs.tune_misses)
                .int("tune_hits", cs.tune_hits)
                .int("tune_entries", cs.tune_entries);
        });
        json.write("BENCH_mixed.json");
    }

    b.finish();
}
