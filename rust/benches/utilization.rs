//! Regenerates §III-A's utilization claims: the int16 and fp32 baseline
//! conv2d reach 93.8% / 93.6% lane utilization at 1x32x512x512.
//! Pass `-- --large` for the full-size input (default 128x128).

mod common;

use common::{large_flag, Bench};
use sparq::report;

fn main() {
    let b = Bench::new("utilization");
    let large = large_flag();
    let rows = b.section("baselines", || report::utilization(large, 3).expect("utilization"));
    print!("{}", report::render_utilization(&rows, large));
    let ok = rows.iter().all(|(_, u, _)| *u > 0.88);
    println!("paper check (>=88% on both baselines): {}", if ok { "holds" } else { "VIOLATED" });
    b.finish();
}
