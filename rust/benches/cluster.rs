//! Multi-core cluster capacity bench (DESIGN.md §Cluster): the full
//! (cores × batch × precision) capacity grid served through round-robin
//! `coordinator::cluster::QnnCluster` frames, plus the determinism and
//! serving smokes that make the grid trustworthy.
//!
//! What it asserts (CI runs this as a smoke):
//!
//! * cluster img/s at fmax is STRICTLY increasing in cores for every
//!   fixed (batch, precision) cell with batch >= cores — the makespan
//!   is max-over-cores + a small fixed shard/merge overhead, so adding
//!   cores must help whenever there are enough slots to spread;
//! * a warm rerun of the whole grid is all graph-level cache hits and
//!   reproduces every makespan bit-for-bit;
//! * K-core sharding is bit-identical to the 1-core path: same logits,
//!   same per-slot cycles as a direct `infer_batch_refs` call, and the
//!   K=1 makespan pays zero overhead;
//! * work-steal sharding agrees with round-robin on every per-request
//!   output (the account may differ — scheduling-dependent);
//! * the batched server actually serves through a K-core cluster
//!   (`ServeConfig::cores`) with zero core failures on the clean path.
//!
//! `--json` writes `BENCH_cluster.json` next to the other BENCH files;
//! `sparq bench-check` gates the cycle fields against
//! `ci/bench_baselines/BENCH_cluster.json` at tolerance 0 (img/s and
//! host wall numbers are deliberately not cycle-keyed).

mod common;

use std::sync::Arc;

use common::{json_flag, Bench, Json};
use sparq::config::ServeConfig;
use sparq::coordinator::cluster::{shard_merge_overhead, QnnCluster, ShardPolicy};
use sparq::coordinator::QnnBatchServer;
use sparq::power::LaneReport;
use sparq::qnn::schedule::{QnnPrecision, DEFAULT_QNN_SEED};
use sparq::qnn::QnnGraph;
use sparq::report::{capacity_grid, render_capacity, SweepCtx};
use sparq::runtime::SimQnnModel;
use sparq::{MachinePool, ProcessorConfig};

const CORES: [usize; 4] = [1, 2, 4, 8];
const BATCHES: [u32; 2] = [4, 8];
const IMAGES: usize = 16;

fn main() {
    let b = Bench::new("cluster");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let ctx = SweepCtx::new();
    let precisions: [(&str, QnnPrecision); 2] = [
        ("w2a2", QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }),
        ("w4a4", QnnPrecision::SubByte { w_bits: 4, a_bits: 4 }),
    ];

    // cold grid compiles each (precision, batch) layout once; every
    // core count reuses the same compiled model
    let rows = b.section("grid(cold)", || {
        capacity_grid(&ctx, &CORES, &BATCHES, &precisions, IMAGES).expect("capacity grid")
    });
    print!("{}", render_capacity(&rows, fmax));

    // the acceptance gate: img/s strictly increasing in cores for every
    // fixed (precision, batch) cell with batch >= cores
    for (plabel, _) in &precisions {
        for &batch in &BATCHES {
            let cells: Vec<_> = rows
                .iter()
                .filter(|r| {
                    r.precision == *plabel && r.batch == batch && batch as usize >= r.cores
                })
                .collect();
            for pair in cells.windows(2) {
                assert!(
                    pair[1].img_per_s_fmax > pair[0].img_per_s_fmax,
                    "{plabel} B={batch}: img/s must strictly increase with cores \
                     (K={} {:.0} !> K={} {:.0})",
                    pair[1].cores,
                    pair[1].img_per_s_fmax,
                    pair[0].cores,
                    pair[0].img_per_s_fmax
                );
            }
        }
    }

    // warm rerun: all graph-level hits, bit-identical makespans
    let misses = ctx.cache.stats().misses;
    let warm = b.section("grid(warm)", || {
        capacity_grid(&ctx, &CORES, &BATCHES, &precisions, IMAGES).expect("warm capacity grid")
    });
    assert_eq!(
        ctx.cache.stats().misses,
        misses,
        "warm grid must be all cache hits (no recompilation)"
    );
    for (c, w) in rows.iter().zip(&warm) {
        assert_eq!(
            c.makespan_cycles, w.makespan_cycles,
            "{} B={} K={}: makespan drifted on the warm rerun",
            c.precision, c.batch, c.cores
        );
    }

    // K-vs-1 bit-identity: one compiled model, a direct batched call,
    // a 1-core cluster, and a 4-core cluster must agree on every logit
    // vector and every per-slot cycle count
    b.section("bit_identity(K=4 vs K=1 vs direct)", || {
        let graph = QnnGraph::sparq_cnn();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let model = Arc::new(
            SimQnnModel::compile_batched(&cfg, &graph, prec, DEFAULT_QNN_SEED, &ctx.cache, 8)
                .expect("compile batch-8 model"),
        );
        let inputs: Vec<Vec<f32>> = (0..8usize)
            .map(|i| {
                (0..model.input_len()).map(|k| ((k as u64 * 13 + i as u64) % 4) as f32).collect()
            })
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let pool = MachinePool::new();
        let (direct, _) = model.infer_batch_refs(&pool, &refs).expect("direct batched call");
        let one = QnnCluster::new(Arc::clone(&model), 1, ShardPolicy::RoundRobin);
        let four = QnnCluster::new(Arc::clone(&model), 4, ShardPolicy::RoundRobin);
        let run1 = one.infer_frame(&refs).expect("1-core frame");
        let run4 = four.infer_frame(&refs).expect("4-core frame");
        for (i, d) in direct.iter().enumerate() {
            let r1 = run1.results[i].as_ref().expect("clean 1-core slot");
            let r4 = run4.results[i].as_ref().expect("clean 4-core slot");
            assert_eq!(d, r1, "slot {i}: 1-core cluster must match the direct call");
            assert_eq!(d, r4, "slot {i}: 4-core cluster must match the direct call");
        }
        assert_eq!(run1.account.overhead_cycles, 0, "K=1 pays zero shard/merge overhead");
        assert_eq!(run4.account.overhead_cycles, shard_merge_overhead(4));
        for run in [&run1, &run4] {
            let busiest =
                run.account.per_core.iter().map(|c| c.cycles).max().expect("cores present");
            assert_eq!(
                run.account.makespan_cycles,
                busiest + run.account.overhead_cycles,
                "makespan must be max-over-cores plus the fixed overhead"
            );
        }
        assert!(
            run4.account.makespan_cycles < run1.account.makespan_cycles,
            "4-core makespan must beat 1-core on a full 8-slot frame"
        );

        // work-steal agrees with round-robin on every output
        let steal = QnnCluster::new(Arc::clone(&model), 4, ShardPolicy::WorkSteal);
        let runs = steal.infer_frame(&refs).expect("work-steal frame");
        for (i, d) in direct.iter().enumerate() {
            let rs = runs.results[i].as_ref().expect("clean work-steal slot");
            assert_eq!(&rs.0, &d.0, "slot {i}: work-steal logits must match round-robin");
            assert_eq!(rs.1, d.1, "slot {i}: work-steal slot cycles must match round-robin");
        }
    });
    println!("bit identity: K=4 and work-steal both match the 1-core path exactly");

    // server smoke: the batched server serving through a 4-core cluster
    let snap = b.section("server(cores=4)", || {
        let server = QnnBatchServer::start(
            cfg.clone(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            DEFAULT_QNN_SEED,
            ServeConfig {
                workers: 1,
                batch_window_us: 20_000,
                queue_depth: 64,
                batch: 8,
                cores: 4,
                ..ServeConfig::default()
            },
            &ctx.cache,
        )
        .expect("server start");
        assert_eq!(server.cores(), 4, "the serve config must reach the cluster");
        let image_len = server.image_len();
        let mut pending = Vec::new();
        for i in 0..32usize {
            let img: Vec<f32> =
                (0..image_len).map(|k| ((k as u64 * 7 + i as u64) % 4) as f32).collect();
            pending.push(server.submit(img).unwrap_or_else(|e| panic!("submit {i}: {e}")));
        }
        let mut served = 0usize;
        for rx in pending {
            served += matches!(rx.recv(), Ok(Ok(_))) as usize;
        }
        assert_eq!(served, 32, "every submitted request must be served");
        let health = server.health();
        assert_eq!(health.cores_alive, 4, "all four cores must stay up on the clean path");
        server.shutdown()
    });
    println!(
        "server: {} requests in {} batches over 4 cores, {} core failure(s)",
        snap.completed, snap.batches, snap.core_failures
    );
    assert_eq!(snap.completed, 32);
    assert_eq!(snap.core_failures, 0, "the clean path must not record core failures");

    if json_flag() {
        let mut json = Json::new();
        json.str("bench", "cluster").int("images", IMAGES as u64).num("fmax_ghz", fmax);
        json.obj("grid", |j| {
            for r in &rows {
                j.obj(&format!("c{}_b{}_{}", r.cores, r.batch, r.precision), |j| {
                    j.int("makespan_cycles", r.makespan_cycles)
                        .int("slot_cycles", r.slot_cycles)
                        .int("preamble_cycles", r.preamble_cycles)
                        .int("overhead_cycles", r.overhead_cycles)
                        .num("cycles_per_image", r.cycles_per_image)
                        .num("images_per_s_at_fmax", r.img_per_s_fmax)
                        .num("host_images_per_s", r.wall_img_per_s);
                });
            }
        });
        json.obj("serve", |j| {
            j.int("completed", snap.completed)
                .int("batches", snap.batches)
                .int("core_failures", snap.core_failures);
        });
        json.write("BENCH_cluster.json");
    }

    b.finish();
}
