//! Regenerates the paper's Fig. 4: ops/cycle for every conv2d
//! implementation (int16, native W3A3/W2A2/W1A1, vmacsr LP/ULP) with a
//! 7x7 kernel.  Pass `-- --large` for the paper's full 32x512x512.
//!
//! The sweep runs twice against one `SweepCtx`: the warm pass re-uses
//! every compiled instruction stream from the program cache (no
//! re-emission) and must reproduce the cold pass bit-for-bit.

mod common;

use common::{large_flag, Bench};
use sparq::kernels::ConvDims;
use sparq::report::{self, SweepCtx};

fn main() {
    let b = Bench::new("fig4");
    let large = large_flag();
    let ctx = SweepCtx::new();
    let rows = b.section("simulate all 6 implementations (cold)", || {
        report::fig4_with(&ctx, large, 42).expect("fig4")
    });
    let warm = b.section("repeat sweep (cached programs, pooled machines)", || {
        report::fig4_with(&ctx, large, 42).expect("fig4 warm")
    });
    for (c, w) in rows.iter().zip(&warm) {
        assert_eq!(c.cycles, w.cycles, "warm rerun diverged on {}", c.label);
    }
    let cs = ctx.cache.stats();
    println!(
        "cache: {} compiles, {} hits on the warm pass; pool: {} machines for {} runs",
        cs.misses,
        cs.hits,
        ctx.pool.stats().created,
        cs.hits + cs.misses
    );
    print!("{}", report::render_fig4(&rows, ConvDims::fig4(large)));

    // paper-shape assertions (soft: print, don't panic, so partial
    // regressions still produce the table)
    let sp = |l: &str| rows.iter().find(|r| r.label.starts_with(l)).map(|r| r.speedup_vs_int16);
    let ulp = sp("ULP").unwrap_or(0.0);
    let lp = sp("LP").unwrap_or(0.0);
    println!(
        "paper check: W2A2 (ULP) {:.2}x vs paper 3.2x | W4A4 (LP) {:.2}x vs paper 1.7x",
        ulp, lp
    );
    b.finish();
}
