//! Regenerates the paper's Fig. 4: ops/cycle for every conv2d
//! implementation (int16, native W3A3/W2A2/W1A1, vmacsr LP/ULP) with a
//! 7x7 kernel.  Pass `-- --large` for the paper's full 32x512x512.

mod common;

use common::{large_flag, Bench};
use sparq::kernels::ConvDims;
use sparq::report;

fn main() {
    let b = Bench::new("fig4");
    let large = large_flag();
    let rows = b.section("simulate all 6 implementations", || {
        report::fig4(large, 42).expect("fig4")
    });
    print!("{}", report::render_fig4(&rows, ConvDims::fig4(large)));

    // paper-shape assertions (soft: print, don't panic, so partial
    // regressions still produce the table)
    let sp = |l: &str| rows.iter().find(|r| r.label.starts_with(l)).map(|r| r.speedup_vs_int16);
    let ulp = sp("ULP").unwrap_or(0.0);
    let lp = sp("LP").unwrap_or(0.0);
    println!(
        "paper check: W2A2 (ULP) {:.2}x vs paper 3.2x | W4A4 (LP) {:.2}x vs paper 1.7x",
        ulp, lp
    );
    b.finish();
}
