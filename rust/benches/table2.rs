//! Regenerates the paper's Table II: physical implementation of the Ara
//! and Sparq lanes (cell area, fmax, power) from the GF22FDX-calibrated
//! component model, plus the derived energy-efficiency comparison.

mod common;

use common::Bench;
use sparq::power::LaneReport;
use sparq::report;
use sparq::ProcessorConfig;

fn main() {
    let b = Bench::new("table2");
    let (ara, sq) = report::table2();
    print!("{}", report::render_table2(&ara, &sq));

    println!("\nper-component breakdown (Sparq lane):");
    for c in &sq.components {
        println!(
            "  {:<22} {:>8.4} mm2 {:>7.1} mW  path {:>5.3} ns",
            c.name, c.area_mm2, c.power_mw, c.path_ns
        );
    }

    // derived: energy efficiency of the headline conv throughputs
    let rows = b.section("fig4 throughputs for efficiency", || {
        report::fig4(false, 42).expect("fig4")
    });
    let sq_eff = LaneReport::for_config(&ProcessorConfig::sparq());
    let ara_eff = LaneReport::for_config(&ProcessorConfig::ara());
    println!("\nenergy efficiency (ops/nJ at lane fmax):");
    for r in &rows {
        let lane = if r.label.contains("W3A3") || r.label.contains("W2A2-conv2d") || r.label.contains("W1A1") {
            &ara_eff
        } else {
            &sq_eff
        };
        println!("  {:<28} {:>7.2} ops/nJ", r.label, lane.ops_per_nj(r.ops_per_cycle));
    }
    b.finish();
}
