//! Simulator performance bench — the §Perf hot path.  Measures host
//! throughput of the functional+timing simulator (element-ops/s and
//! instructions/s) on the Fig. 4 inner loop, so optimization work has a
//! stable number to move.

mod common;

use common::{large_flag, Bench};
use std::time::Instant;

use sparq::arch::ProcessorConfig;
use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
use sparq::ulppack::RegionMode;

fn main() {
    let b = Bench::new("simspeed");
    let large = large_flag();
    let dims = if large { ConvDims::fig4(true) } else { ConvDims::fig4(false) };

    for (label, variant) in [
        ("int16", ConvVariant::Int16),
        ("vmacsr-ulp-w2a2", ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper }),
        ("native-w1a1", ConvVariant::Native { w_bits: 1, a_bits: 1 }),
    ] {
        let (wb, ab) = variant.bits();
        let wl = Workload::random(dims, wb, ab, 9);
        let cfg = if matches!(variant, ConvVariant::Native { .. }) {
            ProcessorConfig::ara()
        } else {
            ProcessorConfig::sparq()
        };
        let t = Instant::now();
        let run = run_conv(&cfg, &wl, variant).expect(label);
        let dt = t.elapsed().as_secs_f64();
        let eops = run.report.stats.element_ops as f64;
        let insts = run.report.stats.cycles; // proxy scale
        println!(
            "  {label:<18} host {dt:>6.3}s | {:>7.1} M element-ops/s | sim {} cycles ({:.1} sim-Mcycles/s)",
            eops / dt / 1e6,
            insts,
            insts as f64 / dt / 1e6,
        );
    }
    b.finish();
}
