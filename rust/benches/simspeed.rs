//! Simulator performance bench — the §Perf hot path.  Measures host
//! throughput of the functional+timing simulator (element-ops/s and
//! instructions/s) on the Fig. 4 inner loop, so optimization work has a
//! stable number to move.
//!
//! The `cached vs uncached` section is the compile-once/execute-many
//! acceptance check: a Fig. 4-style repeated sweep through the program
//! cache + machine pool must beat the seed's rebuild-every-call path
//! while producing bit-identical conv outputs and cycle counts.

mod common;

use common::{large_flag, Bench};
use std::time::Instant;

use sparq::arch::ProcessorConfig;
use sparq::kernels::{
    run_conv, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload,
};
use sparq::sim::MachinePool;
use sparq::ulppack::RegionMode;

fn main() {
    let b = Bench::new("simspeed");
    let large = large_flag();
    let dims = if large { ConvDims::fig4(true) } else { ConvDims::fig4(false) };

    for (label, variant) in [
        ("int16", ConvVariant::Int16),
        ("vmacsr-ulp-w2a2", ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper }),
        ("native-w1a1", ConvVariant::Native { w_bits: 1, a_bits: 1 }),
    ] {
        let (wb, ab) = variant.bits();
        let wl = Workload::random(dims, wb, ab, 9);
        let cfg = if matches!(variant, ConvVariant::Native { .. }) {
            ProcessorConfig::ara()
        } else {
            ProcessorConfig::sparq()
        };
        let t = Instant::now();
        let run = run_conv(&cfg, &wl, variant).expect(label);
        let dt = t.elapsed().as_secs_f64();
        let eops = run.report.stats.element_ops as f64;
        let insts = run.report.stats.cycles; // proxy scale
        println!(
            "  {label:<18} host {dt:>6.3}s | {:>7.1} M element-ops/s | sim {} cycles ({:.1} sim-Mcycles/s)",
            eops / dt / 1e6,
            insts,
            insts as f64 / dt / 1e6,
        );
    }

    // ---- compile-once/execute-many vs rebuild-every-call ----
    b.section("cached vs uncached (Fig. 4-style repeated sweep)", || {
        let reps = if large { 3 } else { 5 };
        let cfg = ProcessorConfig::sparq();
        let variant = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
        let wl = Workload::random(dims, 2, 2, 9);

        // the seed's path: rebuild the machine + instruction stream per rep
        let t = Instant::now();
        let mut cold_outs = Vec::new();
        let mut cold_cycles = Vec::new();
        for _ in 0..reps {
            let run = run_conv(&cfg, &wl, variant).expect("uncached");
            cold_outs = run.out.read_ints(&run.machine.mem).expect("read");
            cold_cycles.push(run.report.stats.cycles);
        }
        let t_uncached = t.elapsed().as_secs_f64();

        // the cached path: compile once, execute on a pooled machine
        let cache = ProgramCache::new();
        let pool = MachinePool::new();
        let t = Instant::now();
        let mut warm_outs = Vec::new();
        let mut warm_cycles = Vec::new();
        for _ in 0..reps {
            let cc = cache
                .get_or_compile(&cfg, &wl, variant, EngineOpts::default())
                .expect("compile");
            let mut m = pool.acquire(&cfg, cc.mem_bytes);
            let rep = cc.execute(&mut m, &wl).expect("execute");
            warm_outs = cc.out.read_ints(&m.mem).expect("read");
            warm_cycles.push(rep.stats.cycles);
            pool.release(m);
        }
        let t_cached = t.elapsed().as_secs_f64();

        // correctness gate: identical outputs and identical cycle counts
        assert_eq!(cold_outs, warm_outs, "cached outputs diverged");
        assert_eq!(cold_cycles, warm_cycles, "cached cycle counts diverged");
        let cs = cache.stats();
        assert_eq!(cs.misses, 1, "program must compile exactly once");
        assert_eq!(cs.hits as usize, reps - 1);

        println!(
            "  {reps} reps | rebuild-every-call {t_uncached:.3}s | compile-once {t_cached:.3}s | {:.2}x faster",
            t_uncached / t_cached
        );
        println!(
            "  identical outputs ({} elems) and cycle counts ({} cycles); cache: 1 compile + {} hits; pool: {} machine(s) created, {} reuses",
            warm_outs.len(),
            warm_cycles[0],
            cs.hits,
            pool.stats().created,
            pool.stats().reused,
        );
    });

    b.finish();
}
