//! Simulator performance bench — the §Perf hot path.  Measures host
//! throughput of the functional+timing simulator (element-ops/s and
//! instructions/s) on the Fig. 4 inner loop, so optimization work has a
//! stable number to move.
//!
//! Four acceptance sections:
//!
//! * per-variant host throughput through the full compiled path;
//! * `compiled vs seed path` — the same E8 vmacsr inner-loop program
//!   executed by the interpreting `Machine::run` (the seed engine) and
//!   by the pre-compiled SWAR `Machine::run_compiled`, with identical
//!   memory and cycle counts asserted;
//! * `fused plan vs per-uop engine` — the superinstruction-fusion
//!   check: the same E8 vmacsr inner loop through the fused
//!   `run_compiled` plan and the retained per-uop
//!   `run_compiled_unfused` engine, identical per-rep cycles and
//!   memory asserted, host-time reduction gated at >= 5x;
//! * `cached vs uncached` — the compile-once/execute-many check: a
//!   Fig. 4-style repeated sweep through the program cache + machine
//!   pool must beat rebuild-every-call bit-identically.
//!
//! `-- --json` additionally writes `BENCH_simspeed.json` (host
//! element-ops/s, sim-Mcycles/s, cached-vs-uncached ratio per variant,
//! compiled-vs-seed speedup, fused-vs-uops speedup + gated sim cycles)
//! so the perf trajectory is tracked across PRs; CI uploads it as an
//! artifact.

mod common;

use common::{json_flag, large_flag, Bench, Json};
use std::time::Instant;

use sparq::arch::ProcessorConfig;
use sparq::kernels::{
    compile_conv, run_conv, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload,
};
use sparq::sim::{Machine, MachinePool};
use sparq::ulppack::RegionMode;

fn main() {
    let b = Bench::new("simspeed");
    let large = large_flag();
    let dims = if large { ConvDims::fig4(true) } else { ConvDims::fig4(false) };
    let mut json = Json::new();
    json.str("bench", "simspeed").int("large", large as u64);

    let mut variant_stats: Vec<(String, f64, f64, u64, f64)> = Vec::new();
    for (label, variant) in [
        ("int16", ConvVariant::Int16),
        ("vmacsr-ulp-w2a2", ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper }),
        ("native-w1a1", ConvVariant::Native { w_bits: 1, a_bits: 1 }),
    ] {
        let (wb, ab) = variant.bits();
        let wl = Workload::random(dims, wb, ab, 9);
        let cfg = if matches!(variant, ConvVariant::Native { .. }) {
            ProcessorConfig::ara()
        } else {
            ProcessorConfig::sparq()
        };
        let t = Instant::now();
        let run = run_conv(&cfg, &wl, variant).expect(label);
        let dt = t.elapsed().as_secs_f64();
        let eops = run.report.stats.element_ops as f64;
        let insts = run.report.stats.cycles; // proxy scale
        println!(
            "  {label:<18} host {dt:>6.3}s | {:>7.1} M element-ops/s | sim {} cycles ({:.1} sim-Mcycles/s)",
            eops / dt / 1e6,
            insts,
            insts as f64 / dt / 1e6,
        );
        variant_stats.push((label.to_string(), dt, eops / dt, insts, insts as f64 / dt));
    }

    // ---- compiled micro-ops vs the seed interpreter ----
    let (seed_s, comp_s, seed_eops, comp_eops) =
        b.section("compiled vs seed path (E8 vmacsr inner loop)", || {
            let reps = if large { 2 } else { 6 };
            let cfg = ProcessorConfig::sparq();
            // ULP W2A2 is the paper's headline kernel: an E8 vmacsr
            // inner loop with slides and widening-spill drains
            let variant = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
            let wl = Workload::random(dims, 2, 2, 9);
            let cc = compile_conv(&cfg, &wl, variant).expect("compile");
            let cp = cc.compiled.as_ref().expect("legal stream must pre-compile");
            let sc = cp.strategy_counts();
            println!(
                "  strategy mix: {} bulk | {} swar | {} generic | {} fused micro-ops",
                sc.bulk, sc.swar, sc.generic, sc.fused
            );

            // two machines, identically bound once; each engine re-runs
            // the same stream in place (state drift is identical on
            // both sides, so outputs/cycles must stay equal rep by rep)
            let mut m_seed = Machine::new(cfg.clone(), cc.mem_bytes);
            let mut m_comp = Machine::new(cfg.clone(), cc.mem_bytes);
            sparq::kernels::conv_engine::bind(&mut m_seed, &wl, &cc).expect("bind");
            sparq::kernels::conv_engine::bind(&mut m_comp, &wl, &cc).expect("bind");

            let t = Instant::now();
            let mut seed_eops = 0u64;
            let mut seed_cycles = Vec::new();
            for _ in 0..reps {
                let r = m_seed.run(&cc.prog).expect("seed run");
                seed_eops += r.stats.element_ops;
                seed_cycles.push(r.stats.cycles);
            }
            let seed_s = t.elapsed().as_secs_f64();

            let t = Instant::now();
            let mut comp_eops = 0u64;
            let mut comp_cycles = Vec::new();
            for _ in 0..reps {
                let r = m_comp.run_compiled(cp).expect("compiled run");
                comp_eops += r.stats.element_ops;
                comp_cycles.push(r.stats.cycles);
            }
            let comp_s = t.elapsed().as_secs_f64();

            assert_eq!(seed_cycles, comp_cycles, "engines disagree on cycle counts");
            assert_eq!(
                m_seed.mem.read(0, m_seed.mem.size()).unwrap(),
                m_comp.mem.read(0, m_comp.mem.size()).unwrap(),
                "engines disagree on memory"
            );
            let se = seed_eops as f64 / seed_s;
            let ce = comp_eops as f64 / comp_s;
            println!(
                "  {reps} reps | seed {seed_s:.3}s ({:.1} M eops/s) | compiled {comp_s:.3}s ({:.1} M eops/s) | {:.2}x host speedup",
                se / 1e6,
                ce / 1e6,
                ce / se
            );
            (seed_s, comp_s, se, ce)
        });

    // ---- fused execution plan vs the retained per-uop engine ----
    let (unf_s, fus_s, fused_cycles_per_rep, plan_blocks, fused_blocks, fused_uops) =
        b.section("fused plan vs per-uop engine (E8 vmacsr inner loop)", || {
            use sparq::isa::{Lmul, Sew, VOp};
            use sparq::kernels::asm::Asm;
            let reps = if large { 60 } else { 20 };
            let cfg = ProcessorConfig::sparq();
            // the conv inner-loop idiom, distilled: short-vl E8 strips
            // (load -> vmacsr x4 -> slide -> 4 contiguous spills) where
            // per-uop dispatch + accounting dominates host time — the
            // shape superinstruction fusion exists for
            let mut a = Asm::new("fused-inner-loop", cfg.vlen_bits);
            a.setvl(8, Sew::E8, Lmul::M1);
            let (in_base, out_base) = (0x1000u64, 0x8000u64);
            let iters = 400u64;
            for it in 0..iters {
                a.vle(Sew::E8, 8, in_base + it * 8);
                for k in 0..4u8 {
                    a.vmacsr_weight(k, 8, 0x9E + k as u64);
                }
                a.vi(VOp::SlideDown, 8, 8, 1);
                for k in 0..4u64 {
                    a.vse(Sew::E8, k as u8, out_base + it * 32 + k * 8);
                }
                a.loop_overhead();
            }
            let prog = a.finish(0);
            let cp = sparq::sim::CompiledProgram::compile(&prog, &cfg).expect("compile");
            let (plan_blocks, fused_blocks, fused_uops, _) = cp.plan_stats();
            let sc = cp.strategy_counts();
            println!(
                "  plan: {plan_blocks} blocks, {fused_blocks} fused ({fused_uops} uops) | mix {} bulk | {} swar | {} fused",
                sc.bulk, sc.swar, sc.fused
            );
            assert!(fused_blocks >= iters, "every iteration's spill run must fuse");

            // identically-bound machines; each engine re-runs the same
            // stream in place (the accumulator drift is identical on
            // both sides), with one untimed warm-up run each
            let mut m_fus = Machine::new(cfg.clone(), 1 << 16);
            let mut m_unf = Machine::new(cfg.clone(), 1 << 16);
            let input: Vec<u8> =
                (0..(iters as usize * 8)).map(|i| (i as u32).wrapping_mul(2654435761) as u8).collect();
            m_fus.mem.write(in_base, &input).expect("bind input");
            m_unf.mem.write(in_base, &input).expect("bind input");
            m_fus.run_compiled(&cp).expect("warm-up");
            m_unf.run_compiled_unfused(&cp).expect("warm-up");

            let t = Instant::now();
            let mut unf_cycles = Vec::new();
            for _ in 0..reps {
                let r = m_unf.run_compiled_unfused(&cp).expect("unfused run");
                unf_cycles.push(r.stats.cycles);
            }
            let unf_s = t.elapsed().as_secs_f64();

            let t = Instant::now();
            let mut fus_cycles = Vec::new();
            let mut fused_seen = (0u64, 0u64);
            for _ in 0..reps {
                let r = m_fus.run_compiled(&cp).expect("fused run");
                fus_cycles.push(r.stats.cycles);
                fused_seen = (r.fused.blocks, r.fused.uops);
            }
            let fus_s = t.elapsed().as_secs_f64();

            // the non-negotiable invariant: identical simulated cycles
            // (rep by rep) and identical memory, fused or not
            assert_eq!(unf_cycles, fus_cycles, "fusion moved cycle counts");
            assert_eq!(
                m_unf.mem.read(0, m_unf.mem.size()).unwrap(),
                m_fus.mem.read(0, m_fus.mem.size()).unwrap(),
                "fusion changed memory"
            );
            assert_eq!(fused_seen, (fused_blocks, fused_uops), "report fused counters");
            let speedup = unf_s / fus_s;
            println!(
                "  {reps} reps | per-uop {unf_s:.3}s | fused plan {fus_s:.3}s | {speedup:.2}x host speedup ({} sim cycles/rep)",
                fus_cycles[0]
            );
            assert!(
                speedup >= 5.0,
                "fused plan must cut host time >= 5x on the inner loop (got {speedup:.2}x)"
            );
            (unf_s, fus_s, fus_cycles[0], plan_blocks as u64, fused_blocks, fused_uops)
        });

    // ---- compile-once/execute-many vs rebuild-every-call ----
    let (t_uncached, t_cached) =
        b.section("cached vs uncached (Fig. 4-style repeated sweep)", || {
            let reps = if large { 3 } else { 5 };
            let cfg = ProcessorConfig::sparq();
            let variant = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper };
            let wl = Workload::random(dims, 2, 2, 9);

            // the seed's path: rebuild the machine + instruction stream per rep
            let t = Instant::now();
            let mut cold_outs = Vec::new();
            let mut cold_cycles = Vec::new();
            for _ in 0..reps {
                let run = run_conv(&cfg, &wl, variant).expect("uncached");
                cold_outs = run.out.read_ints(&run.machine.mem).expect("read");
                cold_cycles.push(run.report.stats.cycles);
            }
            let t_uncached = t.elapsed().as_secs_f64();

            // the cached path: compile once, execute on a pooled machine
            let cache = ProgramCache::new();
            let pool = MachinePool::new();
            let t = Instant::now();
            let mut warm_outs = Vec::new();
            let mut warm_cycles = Vec::new();
            for _ in 0..reps {
                let cc = cache
                    .get_or_compile(&cfg, &wl, variant, EngineOpts::default())
                    .expect("compile");
                let mut m = pool.acquire(&cfg, cc.mem_bytes);
                let rep = cc.execute(&mut m, &wl).expect("execute");
                warm_outs = cc.out.read_ints(&m.mem).expect("read");
                warm_cycles.push(rep.stats.cycles);
                pool.release(m);
            }
            let t_cached = t.elapsed().as_secs_f64();

            // correctness gate: identical outputs and identical cycle counts
            assert_eq!(cold_outs, warm_outs, "cached outputs diverged");
            assert_eq!(cold_cycles, warm_cycles, "cached cycle counts diverged");
            let cs = cache.stats();
            assert_eq!(cs.misses, 1, "program must compile exactly once");
            assert_eq!(cs.hits as usize, reps - 1);

            println!(
                "  {reps} reps | rebuild-every-call {t_uncached:.3}s | compile-once {t_cached:.3}s | {:.2}x faster",
                t_uncached / t_cached
            );
            println!(
                "  identical outputs ({} elems) and cycle counts ({} cycles); cache: 1 compile + {} hits; pool: {} machine(s) created, {} reuses",
                warm_outs.len(),
                warm_cycles[0],
                cs.hits,
                pool.stats().created,
                pool.stats().reused,
            );
            (t_uncached, t_cached)
        });

    if json_flag() {
        json.obj("variants", |j| {
            for (label, dt, eops_s, cycles, mcyc_s) in &variant_stats {
                j.obj(label, |j| {
                    j.num("host_s", *dt)
                        .num("element_ops_per_s", *eops_s)
                        .int("sim_cycles", *cycles)
                        .num("sim_cycles_per_s", *mcyc_s);
                });
            }
        });
        json.obj("compiled_vs_seed", |j| {
            j.num("seed_s", seed_s)
                .num("compiled_s", comp_s)
                .num("seed_element_ops_per_s", seed_eops)
                .num("compiled_element_ops_per_s", comp_eops)
                .num("speedup", comp_eops / seed_eops);
        });
        json.obj("fused_vs_uops", |j| {
            j.num("unfused_s", unf_s)
                .num("fused_s", fus_s)
                .num("host_speedup", unf_s / fus_s)
                .int("sim_cycles", fused_cycles_per_rep)
                .int("plan_blocks", plan_blocks)
                .int("fused_blocks", fused_blocks)
                .int("fused_uops", fused_uops);
        });
        json.obj("cached_vs_uncached", |j| {
            j.num("uncached_s", t_uncached)
                .num("cached_s", t_cached)
                .num("ratio", t_uncached / t_cached);
        });
        json.write("BENCH_simspeed.json");
    }

    b.finish();
}
