//! Regenerates the paper's Fig. 5: relative speedup over int16-conv2d
//! across the overflow-free precision region — (a) native RVV on Ara,
//! (b) vmacsr on Sparq.  Pass `-- --large` for the paper's 32x256x256.
//!
//! Both grids share one `SweepCtx`: the int16 baseline compiles once
//! and the 5b grid re-executes it from the program cache.

mod common;

use common::{large_flag, Bench};
use sparq::kernels::ConvDims;
use sparq::report::{self, SweepCtx};

fn main() {
    let b = Bench::new("fig5");
    let large = large_flag();
    let dims = ConvDims::fig5(large);
    let ctx = SweepCtx::new();
    let native =
        b.section("native grid (Fig. 5a)", || report::fig5_with(&ctx, false, large, 7).unwrap());
    print!("{}", report::render_fig5(&native, false, dims));
    println!();
    let vmacsr =
        b.section("vmacsr grid (Fig. 5b)", || report::fig5_with(&ctx, true, large, 7).unwrap());
    print!("{}", report::render_fig5(&vmacsr, true, dims));

    let runnable_native = native.iter().filter(|c| c.speedup.is_some()).count();
    let runnable_vmacsr = vmacsr.iter().filter(|c| c.speedup.is_some()).count();
    println!(
        "\npaper check: vmacsr region ({runnable_vmacsr} points) wider than native ({runnable_native}) — \
         'higher precision range without modifying the algorithm'"
    );
    let cs = ctx.cache.stats();
    println!(
        "cache: {} compiles, {} hits (shared int16 baseline across grids)",
        cs.misses, cs.hits
    );
    b.finish();
}
