//! Batched serving throughput bench (DESIGN.md §Serving): the SparqCNN
//! W2A2 compiled under the batch-B arena layout for B in {1, 2, 4, 8},
//! served in full batches on the warm cache path.
//!
//! What it asserts (CI runs this as a smoke):
//!
//! * img/s at fmax is STRICTLY increasing from B=1 to B=8 — per-slot
//!   cycles are batch-invariant, so the per-batch weight-pack preamble
//!   is the only amortized term and the ordering is deterministic;
//! * a warm rerun of the whole sweep is all graph-level cache hits
//!   (nothing recompiles, nothing re-tunes);
//! * the batched server path executes real batches (fill histogram,
//!   queue metrics, deterministic cycle-latency percentiles).
//!
//! `--json` writes `BENCH_serve.json` next to the other BENCH files;
//! `sparq bench-check` gates the cycle fields against
//! `ci/bench_baselines/BENCH_serve.json`.

mod common;

use common::{json_flag, Bench, Json};
use sparq::config::ServeConfig;
use sparq::coordinator::QnnBatchServer;
use sparq::power::LaneReport;
use sparq::qnn::schedule::{QnnPrecision, DEFAULT_QNN_SEED};
use sparq::qnn::QnnGraph;
use sparq::report::{render_throughput, throughput_sweep, SweepCtx};
use sparq::ProcessorConfig;

const BATCHES: [u32; 4] = [1, 2, 4, 8];
const IMAGES: usize = 32;

fn main() {
    let b = Bench::new("serve_throughput");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let ctx = SweepCtx::new();

    // cold sweep compiles each batch layout once
    let rows = b.section("sweep(cold)", || {
        throughput_sweep(&ctx, &BATCHES, IMAGES).expect("throughput sweep")
    });
    print!("{}", render_throughput(&rows, fmax));

    // warm rerun: all graph-level hits, bit-identical cycles
    let misses = ctx.cache.stats().misses;
    let warm = b.section("sweep(warm)", || {
        throughput_sweep(&ctx, &BATCHES, IMAGES).expect("warm throughput sweep")
    });
    assert_eq!(
        ctx.cache.stats().misses,
        misses,
        "warm sweep must be all cache hits (no recompilation)"
    );
    for (c, w) in rows.iter().zip(&warm) {
        assert_eq!(c.slot_cycles, w.slot_cycles, "B={} slot cycles drifted", c.batch);
        assert_eq!(c.preamble_cycles, w.preamble_cycles, "B={} preamble drifted", c.batch);
    }

    // the acceptance gate: strictly increasing img/s from B=1 to B=8
    for pair in rows.windows(2) {
        assert!(
            pair[1].img_per_s_fmax > pair[0].img_per_s_fmax,
            "img/s must strictly increase with batch: B={} {:.0} !> B={} {:.0}",
            pair[1].batch,
            pair[1].img_per_s_fmax,
            pair[0].batch,
            pair[0].img_per_s_fmax
        );
    }

    // server smoke at B=8: real batches through the sharded queue
    let snap = b.section("server(B=8)", || {
        let server = QnnBatchServer::start(
            cfg.clone(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            DEFAULT_QNN_SEED,
            ServeConfig {
                workers: 1,
                batch_window_us: 20_000,
                queue_depth: 64,
                batch: 8,
                ..ServeConfig::default()
            },
            &ctx.cache,
        )
        .expect("server start");
        let image_len = server.image_len();
        let mut pending = Vec::new();
        for i in 0..48usize {
            let img: Vec<f32> =
                (0..image_len).map(|k| ((k as u64 * 7 + i as u64) % 4) as f32).collect();
            match server.submit(img) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("submit {i}: {e}"),
            }
        }
        let mut served = 0usize;
        for rx in pending {
            served += matches!(rx.recv(), Ok(Ok(_))) as usize;
        }
        assert_eq!(served, 48, "every submitted request must be served");
        server.shutdown()
    });
    let mean_fill = if snap.batches > 0 {
        snap.completed as f64 / snap.batches as f64
    } else {
        0.0
    };
    println!(
        "server: {} requests in {} batches (mean fill {:.1}), p50/p99 = {}/{} cycles, queue depth max {}",
        snap.completed, snap.batches, mean_fill, snap.p50_cycles, snap.p99_cycles, snap.queue_depth_max
    );
    assert!(snap.batches < snap.completed, "B=8 under flood must batch some requests");

    if json_flag() {
        let mut json = Json::new();
        json.str("bench", "serve_throughput").int("images", IMAGES as u64).num("fmax_ghz", fmax);
        json.obj("sweep", |j| {
            for r in &rows {
                j.obj(&format!("b{}", r.batch), |j| {
                    j.int("slot_cycles", r.slot_cycles)
                        .int("preamble_cycles", r.preamble_cycles)
                        .num("cycles_per_image", r.cycles_per_image)
                        .num("images_per_s_at_fmax", r.img_per_s_fmax)
                        .num("host_images_per_s", r.wall_img_per_s);
                });
            }
        });
        json.obj("serve", |j| {
            j.int("completed", snap.completed)
                .int("batches", snap.batches)
                .num("mean_fill", mean_fill)
                .int("p50_cycles", snap.p50_cycles)
                .int("p99_cycles", snap.p99_cycles)
                .int("rejected", snap.rejected)
                .int("queue_depth_max", snap.queue_depth_max.max(0) as u64);
        });
        json.write("BENCH_serve.json");
    }

    b.finish();
}
