//! Batched serving throughput bench (DESIGN.md §Serving): the SparqCNN
//! W2A2 compiled under the batch-B arena layout for B in {1, 2, 4, 8},
//! served in full batches on the warm cache path.
//!
//! What it asserts (CI runs this as a smoke):
//!
//! * img/s at fmax is STRICTLY increasing from B=1 to B=8 — per-slot
//!   cycles are batch-invariant, so the per-batch weight-pack preamble
//!   is the only amortized term and the ordering is deterministic;
//! * a warm rerun of the whole sweep is all graph-level cache hits
//!   (nothing recompiles, nothing re-tunes);
//! * the batched server path executes real batches (fill histogram,
//!   queue metrics, deterministic cycle-latency percentiles);
//! * the slot-reservation front door (`coordinator::ring`) sustains
//!   >= 1M submits/s into a stub consumer, and at low paced load one
//!   shared ring fills strictly better batches than the old layout of
//!   N private per-shard queues.
//!
//! `--json` writes `BENCH_serve.json` next to the other BENCH files;
//! `sparq bench-check` gates the cycle fields against
//! `ci/bench_baselines/BENCH_serve.json` (the front-door numbers are
//! wall-clock, deliberately not cycle-keyed, so the tolerance-0 gate
//! ignores them).

mod common;

use std::time::{Duration, Instant};

use common::{json_flag, Bench, Json};
use sparq::config::ServeConfig;
use sparq::coordinator::ring::{BatchRing, Pop, PushError};
use sparq::coordinator::QnnBatchServer;
use sparq::power::LaneReport;
use sparq::qnn::schedule::{QnnPrecision, DEFAULT_QNN_SEED};
use sparq::qnn::QnnGraph;
use sparq::report::{render_throughput, throughput_sweep, SweepCtx};
use sparq::ProcessorConfig;

const BATCHES: [u32; 4] = [1, 2, 4, 8];
const IMAGES: usize = 32;

fn main() {
    let b = Bench::new("serve_throughput");
    let cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&cfg).fmax_ghz();
    let ctx = SweepCtx::new();

    // cold sweep compiles each batch layout once
    let rows = b.section("sweep(cold)", || {
        throughput_sweep(&ctx, &BATCHES, IMAGES).expect("throughput sweep")
    });
    print!("{}", render_throughput(&rows, fmax));

    // warm rerun: all graph-level hits, bit-identical cycles
    let misses = ctx.cache.stats().misses;
    let warm = b.section("sweep(warm)", || {
        throughput_sweep(&ctx, &BATCHES, IMAGES).expect("warm throughput sweep")
    });
    assert_eq!(
        ctx.cache.stats().misses,
        misses,
        "warm sweep must be all cache hits (no recompilation)"
    );
    for (c, w) in rows.iter().zip(&warm) {
        assert_eq!(c.slot_cycles, w.slot_cycles, "B={} slot cycles drifted", c.batch);
        assert_eq!(c.preamble_cycles, w.preamble_cycles, "B={} preamble drifted", c.batch);
    }

    // the acceptance gate: strictly increasing img/s from B=1 to B=8
    for pair in rows.windows(2) {
        assert!(
            pair[1].img_per_s_fmax > pair[0].img_per_s_fmax,
            "img/s must strictly increase with batch: B={} {:.0} !> B={} {:.0}",
            pair[1].batch,
            pair[1].img_per_s_fmax,
            pair[0].batch,
            pair[0].img_per_s_fmax
        );
    }

    // server smoke at B=8: real batches through the front-door ring
    let snap = b.section("server(B=8)", || {
        let server = QnnBatchServer::start(
            cfg.clone(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            DEFAULT_QNN_SEED,
            ServeConfig {
                workers: 1,
                batch_window_us: 20_000,
                queue_depth: 64,
                batch: 8,
                ..ServeConfig::default()
            },
            &ctx.cache,
        )
        .expect("server start");
        let image_len = server.image_len();
        let mut pending = Vec::new();
        for i in 0..48usize {
            let img: Vec<f32> =
                (0..image_len).map(|k| ((k as u64 * 7 + i as u64) % 4) as f32).collect();
            match server.submit(img) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("submit {i}: {e}"),
            }
        }
        let mut served = 0usize;
        for rx in pending {
            served += matches!(rx.recv(), Ok(Ok(_))) as usize;
        }
        assert_eq!(served, 48, "every submitted request must be served");
        server.shutdown()
    });
    let mean_fill = if snap.batches > 0 {
        snap.completed as f64 / snap.batches as f64
    } else {
        0.0
    };
    println!(
        "server: {} requests in {} batches (mean fill {:.1}), p50/p99 = {}/{} cycles, queue depth max {}",
        snap.completed, snap.batches, mean_fill, snap.p50_cycles, snap.p99_cycles, snap.queue_depth_max
    );
    assert!(snap.batches < snap.completed, "B=8 under flood must batch some requests");

    // front door, raw: 4 producers hammer one ring of 64-slot frames
    // into a stub consumer — the slot-reservation claim path must
    // sustain >= 1M submits/s end to end (every submit delivered)
    const PRODUCERS: usize = 4;
    const PER: usize = 250_000;
    let submits = (PRODUCERS * PER) as u64;
    let submits_per_s = b.section("front_door(submits)", || {
        let ring: BatchRing<u64> = BatchRing::new(64, 64, Duration::from_micros(100));
        let ring_ref = &ring;
        std::thread::scope(|s| {
            let consumer = s.spawn(move || {
                let mut n = 0u64;
                loop {
                    match ring_ref.pop(Duration::from_millis(5)) {
                        Pop::Batch(items, _) => n += items.len() as u64,
                        Pop::Idle => {}
                        Pop::Closed => return n,
                    }
                }
            });
            let t0 = Instant::now();
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|p| {
                    s.spawn(move || {
                        for k in 0..PER {
                            let mut v = (p * PER + k) as u64;
                            loop {
                                match ring_ref.push(v) {
                                    Ok(_) => break,
                                    Err((PushError::Full, back)) => {
                                        v = back;
                                        std::thread::yield_now();
                                    }
                                    Err((PushError::Closed, _)) => {
                                        unreachable!("nobody closes mid-bench")
                                    }
                                }
                            }
                        }
                    })
                })
                .collect();
            for p in producers {
                p.join().unwrap();
            }
            let wall = t0.elapsed();
            ring.close();
            let received = consumer.join().unwrap();
            assert_eq!(received, submits, "every submit must be delivered exactly once");
            submits as f64 / wall.as_secs_f64()
        })
    });
    println!("front door: {submits} submits at {submits_per_s:.0} submits/s");
    assert!(
        submits_per_s >= 1_000_000.0,
        "the slot-reservation front door must sustain >= 1M submits/s (got {submits_per_s:.0})"
    );

    // front door, fill: the same paced trickle (one rider every 200us,
    // window 5ms) through ONE shared ring vs round-robin over 4
    // private rings — the old per-shard layout splits the offered load
    // N ways, so each private ring sees a quarter of the arrival rate
    // and its mean batch fill must be strictly worse
    let ring_fill = b.section("front_door(fill shared)", || {
        let rings = [BatchRing::new(8, 8, Duration::from_millis(5))];
        paced_mean_fill(&rings, 96, Duration::from_micros(200))
    });
    let sharded_fill = b.section("front_door(fill sharded)", || {
        let rings: Vec<BatchRing<u64>> =
            (0..4).map(|_| BatchRing::new(8, 8, Duration::from_millis(5))).collect();
        paced_mean_fill(&rings, 96, Duration::from_micros(200))
    });
    println!(
        "front door: mean batch fill {ring_fill:.2} shared vs {sharded_fill:.2} sharded at low load"
    );
    assert!(
        ring_fill > sharded_fill,
        "one shared ring must fill strictly better batches than split queues \
         ({ring_fill:.2} !> {sharded_fill:.2})"
    );

    if json_flag() {
        let mut json = Json::new();
        json.str("bench", "serve_throughput").int("images", IMAGES as u64).num("fmax_ghz", fmax);
        json.obj("sweep", |j| {
            for r in &rows {
                j.obj(&format!("b{}", r.batch), |j| {
                    j.int("slot_cycles", r.slot_cycles)
                        .int("preamble_cycles", r.preamble_cycles)
                        .num("cycles_per_image", r.cycles_per_image)
                        .num("images_per_s_at_fmax", r.img_per_s_fmax)
                        .num("host_images_per_s", r.wall_img_per_s);
                });
            }
        });
        json.obj("serve", |j| {
            j.int("completed", snap.completed)
                .int("batches", snap.batches)
                .num("mean_fill", mean_fill)
                .int("p50_cycles", snap.p50_cycles)
                .int("p99_cycles", snap.p99_cycles)
                .int("rejected", snap.rejected)
                .int("queue_depth_max", snap.queue_depth_max.max(0) as u64);
        });
        // wall-clock numbers: informational, never cycle-gated
        json.obj("front_door", |j| {
            j.int("submits", submits)
                .num("submits_per_s", submits_per_s)
                .num("ring_mean_fill", ring_fill)
                .num("sharded_mean_fill", sharded_fill);
        });
        json.write("BENCH_serve.json");
    }

    b.finish();
}

/// Trickle `n` riders round-robin into `rings` (one every `gap`), one
/// dedicated consumer per ring, and return the mean batch fill across
/// every executed batch.  Every rider must be delivered.
fn paced_mean_fill(rings: &[BatchRing<u64>], n: usize, gap: Duration) -> f64 {
    std::thread::scope(|s| {
        let consumers: Vec<_> = rings
            .iter()
            .map(|r| {
                s.spawn(move || {
                    let mut batches = 0u64;
                    let mut items = 0u64;
                    loop {
                        match r.pop(Duration::from_millis(5)) {
                            Pop::Batch(b, _) => {
                                batches += 1;
                                items += b.len() as u64;
                            }
                            Pop::Idle => {}
                            Pop::Closed => return (batches, items),
                        }
                    }
                })
            })
            .collect();
        for i in 0..n {
            rings[i % rings.len()]
                .push(i as u64)
                .unwrap_or_else(|_| panic!("a low-load push must never be refused"));
            std::thread::sleep(gap);
        }
        // let the trailing window seal naturally before closing so the
        // tail partials are windowed the same way on both layouts
        std::thread::sleep(Duration::from_millis(5));
        for r in rings {
            r.close();
        }
        let (mut batches, mut items) = (0u64, 0u64);
        for c in consumers {
            let (b, i) = c.join().unwrap();
            batches += b;
            items += i;
        }
        assert_eq!(items as usize, n, "every paced rider must be delivered");
        items as f64 / batches.max(1) as f64
    })
}
