"""L2 — SparqCNN: the quantized CNN whose conv layers route through the
L1 packed kernels.

Two forward paths over the same trained parameters:

* ``forward_qat``   — float fake-quant (STE) path used for training and
  for the FP32 reference (bits=None).  Convolutions are
  ``lax.conv_general_dilated`` so training is fast.
* ``forward_packed``— the *deployed* integer path exported to HLO: per
  quantized conv layer, activations are quantized to unsigned levels,
  ULPPACK-packed (L1 pallas kernel), convolved with the packed weights
  via the vmacsr-dataflow pallas kernel, zero-point-corrected and
  rescaled.  This is the graph the rust runtime serves; python never
  runs at inference time.

Architecture (channel-first, 16x16 single-channel inputs, 4 classes):

    conv1 1->16  3x3 same   relu   (stem kept at 8-bit acts, fp weights)
    conv2 16->32 3x3 same   relu   maxpool2          [packed sub-byte]
    conv3 32->32 3x3 same   relu   maxpool2          [packed sub-byte]
    GAP -> fc 32->4

The stem convolution is kept high-precision like most sub-byte QNN
recipes (the paper's Table I models do the same); conv2/conv3 carry the
W/A sub-byte configuration under test.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quant
from .kernels.packed_conv2d import packed_conv2d
from .kernels.ulppack_pack import pack_activations, pack_weights

NUM_CLASSES = 4
STEM_BITS = 8


class QConfig(NamedTuple):
    """Per-model quantization config; ``None`` bits = FP32 everywhere."""

    w_bits: Optional[int]
    a_bits: Optional[int]

    @property
    def is_fp32(self) -> bool:
        return self.w_bits is None

    @property
    def container_bits(self) -> int:
        """LP (16-bit containers) vs ULP (8-bit) — the paper's Fig. 5
        mapping: W+A <= 4 fits the ULP range, otherwise LP."""
        assert self.w_bits is not None and self.a_bits is not None
        return 8 if self.w_bits + self.a_bits <= 4 else 16


def init_params(seed: int = 0) -> dict:
    """He-initialised parameters, channel-first layouts."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)

    def conv_init(key, co, ci, f):
        fan_in = ci * f * f
        return jax.random.normal(key, (co, ci, f, f), jnp.float32) * np.sqrt(2.0 / fan_in)

    return {
        "conv1_w": conv_init(ks[0], 16, 1, 3),
        "conv1_b": jnp.zeros((16,), jnp.float32),
        "conv2_w": conv_init(ks[1], 32, 16, 3),
        "conv2_b": jnp.zeros((32,), jnp.float32),
        "conv3_w": conv_init(ks[2], 32, 32, 3),
        "conv3_b": jnp.zeros((32,), jnp.float32),
        "fc_w": jax.random.normal(ks[3], (NUM_CLASSES, 32), jnp.float32) * 0.1,
        "fc_b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }


def _conv_same(x: jax.Array, w: jax.Array) -> jax.Array:
    """NCHW 'same' convolution (float)."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NCHW", "OIHW", "NCHW")
    )


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def calibrate(params: dict, cfg: QConfig, x_cal: jax.Array) -> dict:
    """One float forward over a calibration batch to fix all scales.

    Returns the frozen quantization state (scales for activations at the
    input of conv2/conv3 and for each quantized weight tensor).
    """
    h1 = jax.nn.relu(_conv_same(x_cal, params["conv1_w"]) + params["conv1_b"][:, None, None])
    h2 = jax.nn.relu(_conv_same(h1, params["conv2_w"]) + params["conv2_b"][:, None, None])
    h2p = _maxpool2(h2)
    qs = {}
    if not cfg.is_fp32:
        qs["a2"] = quant.act_qparams(h1, cfg.a_bits)
        qs["a3"] = quant.act_qparams(h2p, cfg.a_bits)
        qs["w2"] = quant.weight_qparams(params["conv2_w"], cfg.w_bits)
        qs["w3"] = quant.weight_qparams(params["conv3_w"], cfg.w_bits)
    return jax.tree.map(jax.lax.stop_gradient, qs)


def forward_qat(params: dict, qstate: dict, cfg: QConfig, x: jax.Array) -> jax.Array:
    """Float/fake-quant forward (training + FP32 reference). x: (N,1,16,16)."""
    h = jax.nn.relu(_conv_same(x, params["conv1_w"]) + params["conv1_b"][:, None, None])
    if not cfg.is_fp32:
        h = quant.fake_quant_act(h, cfg.a_bits, qstate["a2"])
        w2 = quant.fake_quant_weight(params["conv2_w"], cfg.w_bits, qstate["w2"])
    else:
        w2 = params["conv2_w"]
    h = jax.nn.relu(_conv_same(h, w2) + params["conv2_b"][:, None, None])
    h = _maxpool2(h)
    if not cfg.is_fp32:
        h = quant.fake_quant_act(h, cfg.a_bits, qstate["a3"])
        w3 = quant.fake_quant_weight(params["conv3_w"], cfg.w_bits, qstate["w3"])
    else:
        w3 = params["conv3_w"]
    h = jax.nn.relu(_conv_same(h, w3) + params["conv3_b"][:, None, None])
    h = _maxpool2(h)
    feat = jnp.mean(h, axis=(2, 3))  # GAP -> (N, 32)
    return feat @ params["fc_w"].T + params["fc_b"]


def _sum_conv_same(levels: jax.Array, f: int) -> jax.Array:
    """'Same' conv of integer levels with an all-ones FxF kernel — the
    zero-point correction term, computed by static slicing (int32)."""
    n, c, h, w = levels.shape
    pad = f // 2
    xp = jnp.pad(levels, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    out = jnp.zeros((n, h, w), jnp.int32)
    for i in range(f):
        for j in range(f):
            out = out + xp[:, :, i : i + h, j : j + w].sum(axis=1)
    return out


def _packed_qconv_same(x_levels: jax.Array, w_levels: jax.Array, cfg: QConfig):
    """'Same' packed conv over a batch of unsigned activation levels.

    x_levels: (N, C, H, W) int32; w_levels: (Co, C, F, F) int32 unsigned
    levels (zero-point offset included).  Returns (dot, sum_a) where
    dot[n,o,h,w] = sum a*q  (int32) via the L1 pallas kernel and
    sum_a[n,h,w] is the zero-point correction conv.
    """
    b = cfg.container_bits
    f = w_levels.shape[-1]
    pad = f // 2
    xp = jnp.pad(x_levels, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    packed_w = pack_weights(w_levels, b)
    packed_x = jax.vmap(lambda img: pack_activations(img, b))(xp)
    dot = jax.vmap(lambda img: packed_conv2d(img, packed_w, b))(packed_x)
    return dot, _sum_conv_same(x_levels, f)


def forward_packed(params: dict, qstate: dict, cfg: QConfig, x: jax.Array) -> jax.Array:
    """Deployed integer forward: conv2/conv3 go through the ULPPACK
    pallas kernels with zero-point correction.  Matches the layer math
    the rust Sparq simulator executes."""
    assert not cfg.is_fp32, "packed path needs a quantized config"
    h = jax.nn.relu(_conv_same(x, params["conv1_w"]) + params["conv1_b"][:, None, None])

    for name, scale_a, scale_w in (("conv2", "a2", "w2"), ("conv3", "a3", "w3")):
        w_bits, a_bits = cfg.w_bits, cfg.a_bits
        zp = 2 ** (w_bits - 1) - 1
        s_a, s_w = qstate[scale_a], qstate[scale_w]
        a_lv = quant.quantize_act_levels(h, a_bits, s_a)
        w_lv = quant.quantize_weight_levels(params[f"{name}_w"], w_bits, s_w)
        dot, sum_a = _packed_qconv_same(a_lv, w_lv, cfg)
        acc = dot - zp * sum_a[:, None, :, :]
        y = acc.astype(jnp.float32) * (s_a * s_w) + params[f"{name}_b"][None, :, None, None]
        h = _maxpool2(jax.nn.relu(y))

    feat = jnp.mean(h, axis=(2, 3))
    return feat @ params["fc_w"].T + params["fc_b"]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def loss_fn(params, qstate, cfg, x, y):
    logits = forward_qat(params, qstate, cfg, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


@functools.partial(jax.jit, static_argnames=("cfg", "lr", "momentum"))
def train_step(params, vel, qstate, cfg, x, y, lr=0.05, momentum=0.9):
    l, g = jax.value_and_grad(loss_fn)(params, qstate, cfg, x, y)
    vel = jax.tree.map(lambda v, gi: momentum * v - lr * gi, vel, g)
    params = jax.tree.map(lambda p, v: p + v, params, vel)
    return params, vel, l


def train(params, qstate, cfg, images, labels, steps=400, batch=64, seed=0):
    """Minibatch SGD+momentum; returns (params, losses per 50 steps)."""
    rng = np.random.default_rng(seed)
    vel = jax.tree.map(jnp.zeros_like, params)
    x = jnp.asarray(images)
    y = jnp.asarray(labels)
    n = x.shape[0]
    losses = []
    for step in range(steps):
        idx = rng.integers(0, n, batch)
        params, vel, l = train_step(params, vel, qstate, cfg, x[idx], y[idx])
        if step % 50 == 0 or step == steps - 1:
            losses.append((step, float(l)))
    return params, losses


def accuracy(forward, params, qstate, cfg, images, labels, batch=64) -> float:
    n = images.shape[0]
    correct = 0
    for i in range(0, n, batch):
        logits = forward(params, qstate, cfg, jnp.asarray(images[i : i + batch]))
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(labels[i : i + batch])))
    return correct / n
