"""Synthetic oriented-pattern dataset (the Table-I substitution).

We have no ImageNet; the point of the paper's Table I is that 2-4-bit
QNNs match FP32 accuracy.  We demonstrate the same ordering on a
controlled 4-class texture-classification task that a small CNN can
learn in a few hundred steps: oriented gratings (horizontal, vertical,
diagonal, checkerboard) with random phase, frequency, contrast and
additive noise.  Inputs are (1, 16, 16) in [0, 1], channel-first.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 4
IMG = 16


def make_dataset(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (n,1,16,16) float32 in [0,1], labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, NUM_CLASSES, n)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    imgs = np.empty((n, 1, IMG, IMG), np.float32)
    for i, y in enumerate(ys):
        freq = rng.uniform(0.6, 1.4)
        phase = rng.uniform(0, 2 * np.pi)
        if y == 0:  # horizontal stripes
            base = np.sin(yy * freq + phase)
        elif y == 1:  # vertical stripes
            base = np.sin(xx * freq + phase)
        elif y == 2:  # diagonal stripes
            base = np.sin((xx + yy) * freq * 0.7 + phase)
        else:  # checkerboard
            base = np.sin(xx * freq + phase) * np.sin(yy * freq + phase)
        contrast = rng.uniform(0.35, 1.0)
        noise = rng.normal(0, 0.30, (IMG, IMG)).astype(np.float32)
        img = 0.5 + 0.5 * contrast * base + noise
        imgs[i, 0] = np.clip(img, 0.0, 1.0)
    return imgs, ys.astype(np.int32)


def save_raw(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Write the trivially-parsable binary the rust runtime reads:

    magic 'SPQD' | u32 n | u32 c | u32 h | u32 w | f32 data (n*c*h*w, LE)
    | u8 labels (n).
    """
    n, c, h, w = images.shape
    with open(path, "wb") as f:
        f.write(b"SPQD")
        f.write(np.asarray([n, c, h, w], "<u4").tobytes())
        f.write(images.astype("<f4").tobytes())
        f.write(labels.astype(np.uint8).tobytes())
