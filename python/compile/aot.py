"""AOT compile path: train the QNN, lower every model/kernel variant to
HLO *text*, and write the artifacts the rust runtime serves.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT ``HloModuleProto.serialize()`` —
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
Every lowering uses ``return_tuple=True`` so the rust side unwraps with
``to_tuple1()``.

Artifacts written:

    qnn_fp32.hlo.txt        float reference model       (f32[16,1,16,16] -> f32[16,4])
    qnn_w4a4.hlo.txt        packed-integer QNN, LP      (same signature)
    qnn_w3a3.hlo.txt        packed-integer QNN, LP
    qnn_w2a2.hlo.txt        packed-integer QNN, ULP
    packed_conv2d_lp.hlo.txt   standalone L1 kernel, 16-bit containers
                               (i32[16,18,18] levels, i32[8,16,3,3] levels -> i32[8,16,16])
    packed_conv2d_ulp.hlo.txt  standalone L1 kernel, 8-bit containers
    testset.bin             512 held-out images + labels (see dataset.save_raw)
    train_log.txt           python-side reference accuracies + loss curves
    manifest.txt            machine-readable index of all of the above
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, model
from .kernels.packed_conv2d import packed_conv2d
from .kernels.ulppack_pack import pack_activations, pack_weights

BATCH = 16

QCONFIGS = {
    "fp32": model.QConfig(None, None),
    "w4a4": model.QConfig(4, 4),
    "w3a3": model.QConfig(3, 3),
    "w2a2": model.QConfig(2, 2),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constants as ``constant({...})`` and the rust-side text
    parser silently reads those as zeros — which zeroes out every baked
    model weight (accuracy collapses to chance).  Guarded by
    ``test_aot.py::test_no_elided_constants``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_fn(fn, example_args, path: str) -> None:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


def standalone_kernel(container_bits: int):
    """The L1 packed conv as a self-contained graph over i32 levels
    (the xla 0.1.6 crate has first-class i32 literals; containers and
    packing live inside the graph)."""

    def fn(x_levels, w_levels):
        xp = pack_activations(x_levels, container_bits)
        wp = pack_weights(w_levels, container_bits)
        return (packed_conv2d(xp, wp, container_bits),)

    return fn


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--train-steps", type=int, default=400)
    p.add_argument("--finetune-steps", type=int, default=150)
    p.add_argument("--quick", action="store_true", help="tiny training run (CI)")
    p.add_argument("--seed", type=int, default=7)
    args = p.parse_args()
    if args.quick:
        args.train_steps, args.finetune_steps = 40, 20

    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    t0 = time.time()
    manifest: list[str] = []
    trainlog: list[str] = []

    # ------------------------------------------------------------------
    # Data
    # ------------------------------------------------------------------
    train_x, train_y = dataset.make_dataset(2048, seed=args.seed)
    test_x, test_y = dataset.make_dataset(512, seed=args.seed + 1)
    dataset.save_raw(os.path.join(out, "testset.bin"), test_x, test_y)
    manifest.append(f"data\ttestset\ttestset.bin\tn={len(test_y)}\tc=1\th=16\tw=16\tclasses=4")

    # ------------------------------------------------------------------
    # Train FP32 base, then fine-tune each quantized config from it
    # ------------------------------------------------------------------
    cfg_fp = QCONFIGS["fp32"]
    params = model.init_params(seed=args.seed)
    params, losses = model.train(
        params, {}, cfg_fp, train_x, train_y, steps=args.train_steps, seed=args.seed
    )
    for step, l in losses:
        trainlog.append(f"loss\tfp32\t{step}\t{l:.4f}")
    acc_fp = model.accuracy(
        lambda p, q, c, x: model.forward_qat(p, q, c, x), params, {}, cfg_fp, test_x, test_y
    )
    trainlog.append(f"acc\tfp32\t{acc_fp:.4f}")
    print(f"[aot] fp32 trained: test acc {acc_fp:.4f} ({time.time()-t0:.1f}s)")

    fwd_fp32 = lambda x: (model.forward_qat(params, {}, cfg_fp, x),)
    spec = jax.ShapeDtypeStruct((BATCH, 1, 16, 16), jnp.float32)
    path = "qnn_fp32.hlo.txt"
    export_fn(fwd_fp32, (spec,), os.path.join(out, path))
    manifest.append(f"artifact\tqnn_fp32\t{path}\tbatch={BATCH}\tin=1x16x16\tout=4\tacc_ref={acc_fp:.4f}")

    for name in ("w4a4", "w3a3", "w2a2"):
        cfg = QCONFIGS[name]
        # 2-bit needs a longer fine-tune to recover from the harsher clip
        steps = args.finetune_steps * (3 if name == "w2a2" else 1)
        qstate = model.calibrate(params, cfg, jnp.asarray(train_x[:256]))
        qparams, qlosses = model.train(
            params, qstate, cfg, train_x, train_y, steps=steps, seed=args.seed
        )
        for step, l in qlosses:
            trainlog.append(f"loss\t{name}\t{step}\t{l:.4f}")
        # re-calibrate scales on the fine-tuned weights
        qstate = model.calibrate(qparams, cfg, jnp.asarray(train_x[:256]))
        acc_qat = model.accuracy(model.forward_qat, qparams, qstate, cfg, test_x, test_y)
        acc_pk = model.accuracy(model.forward_packed, qparams, qstate, cfg, test_x[:256], test_y[:256])
        trainlog.append(f"acc\t{name}\tqat={acc_qat:.4f}\tpacked={acc_pk:.4f}")
        print(f"[aot] {name}: qat acc {acc_qat:.4f}, packed-integer acc {acc_pk:.4f} "
              f"({time.time()-t0:.1f}s)")

        fwd = lambda x, qp=qparams, qs=qstate, c=cfg: (model.forward_packed(qp, qs, c, x),)
        path = f"qnn_{name}.hlo.txt"
        export_fn(fwd, (spec,), os.path.join(out, path))
        manifest.append(
            f"artifact\tqnn_{name}\t{path}\tbatch={BATCH}\tin=1x16x16\tout=4"
            f"\twbits={cfg.w_bits}\tabits={cfg.a_bits}\tcontainer={cfg.container_bits}"
            f"\tacc_ref={acc_pk:.4f}"
        )

    # ------------------------------------------------------------------
    # Standalone L1 kernel artifacts (rust <-> simulator cross-check)
    # ------------------------------------------------------------------
    xspec = jax.ShapeDtypeStruct((16, 18, 18), jnp.int32)
    wspec = jax.ShapeDtypeStruct((8, 16, 3, 3), jnp.int32)
    for name, bits in (("lp", 16), ("ulp", 8)):
        path = f"packed_conv2d_{name}.hlo.txt"
        export_fn(standalone_kernel(bits), (xspec, wspec), os.path.join(out, path))
        manifest.append(
            f"artifact\tpacked_conv2d_{name}\t{path}\tc=16\th=18\tw=18\tco=8\tf=3"
            f"\tcontainer={bits}"
        )

    with open(os.path.join(out, "train_log.txt"), "w") as f:
        f.write("\n".join(trainlog) + "\n")
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] wrote {len(manifest)} artifacts to {out} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
