"""Quantizers used by the L2 QNN (QAT forward) and the integer export path.

Activations are quantized *unsigned* (post-ReLU range) and weights are
quantized to unsigned levels around a zero-point — the representation
the ULPPACK containers need (both packed halves must be non-negative).
A weight level ``q`` represents the real value ``(q - zp) * scale`` with
``zp = 2^(W-1) - 1`` (mid-rise symmetric), so the integer conv output is
corrected by ``zp * sum(a_levels)`` per output pixel:

    sum_a sum_w a*(q - zp)*s_w*s_a = s_w*s_a * (dot(a, q) - zp * sum(a))

The correction term ``sum(a)`` is itself a conv2d with all-ones weights
over the activation levels — cheap, and the rust QNN scheduler accounts
its cycles explicitly (see rust/src/qnn).

Gradients: straight-through estimator (STE) — identity inside the clip
range, zero outside — the standard LSQ/PACT-style QAT recipe.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def act_qparams(x: jax.Array, bits: int) -> jax.Array:
    """Calibration: scale so the 99.9th percentile maps to the top level."""
    hi = jnp.percentile(jnp.abs(x), 99.9)
    return jnp.maximum(hi, 1e-5) / (2**bits - 1)


def weight_qparams(w: jax.Array, bits: int) -> jax.Array:
    """SAWB-flavoured symmetric weight scale.

    For >= 3 bits the max-magnitude rule works; at 2 bits (ternary
    levels {-1, 0, +1}) it would zero out every weight below max/2, so
    the scale follows the mean magnitude instead (threshold at
    ~0.75*mean, the classic ternary-networks choice).
    """
    zp = 2 ** (bits - 1) - 1
    if bits <= 2:
        return jnp.maximum(1.5 * jnp.mean(jnp.abs(w)), 1e-5)
    hi = jnp.max(jnp.abs(w))
    return jnp.maximum(hi, 1e-5) / jnp.maximum(zp, 1)


def quantize_act_levels(x: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Unsigned activation levels in [0, 2^bits - 1] (int32)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.int32)


def quantize_weight_levels(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Unsigned weight levels in [0, 2^bits - 2] around zp = 2^(W-1)-1."""
    zp = 2 ** (bits - 1) - 1
    q = jnp.round(w / scale) + zp
    return jnp.clip(q, 0, 2 * zp).astype(jnp.int32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant_act(x: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Quantize-dequantize activations with an STE gradient."""
    lv = quantize_act_levels(x, bits, scale)
    return lv.astype(jnp.float32) * scale


def _fqa_fwd(x, bits, scale):
    y = fake_quant_act(x, bits, scale)
    mask = (x >= 0) & (x <= scale * (2**bits - 1))
    return y, (mask, x, scale)


def _fqa_bwd(bits, res, g):
    mask, x, scale = res
    gx = jnp.where(mask, g, 0.0)
    # LSQ-lite scale gradient: d(quant)/d(scale) ~ (y - x)/scale clipped
    gs = jnp.sum(jnp.where(mask, 0.0, g * jnp.sign(x)))
    return gx, gs


fake_quant_act.defvjp(_fqa_fwd, _fqa_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant_weight(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Quantize-dequantize weights (symmetric, STE gradient)."""
    zp = 2 ** (bits - 1) - 1
    lv = quantize_weight_levels(w, bits, scale)
    return (lv.astype(jnp.float32) - zp) * scale


def _fqw_fwd(w, bits, scale):
    y = fake_quant_weight(w, bits, scale)
    zp = 2 ** (bits - 1) - 1
    mask = jnp.abs(w) <= scale * zp
    return y, (mask,)


def _fqw_bwd(bits, res, g):
    (mask,) = res
    return jnp.where(mask, g, 0.0), jnp.zeros(())


fake_quant_weight.defvjp(_fqw_fwd, _fqw_bwd)
