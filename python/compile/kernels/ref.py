"""Pure-jnp reference oracles for every Sparq kernel.

This file is the single source of truth for the ULPPACK / ``vmacsr``
arithmetic used across the whole repository (python pallas kernels, the
rust functional simulator, and the rust kernel-stream builders all match
these semantics; the rust side re-implements them and the cross-layer
integration tests assert equality).

ULPPACK P1 packing with k=2 operands per container
--------------------------------------------------

A *container* is an unsigned B-bit integer (B = 16 for the LP range,
B = 8 for the ULP range) holding two sub-byte operands in its two
S = B/2 bit halves.  Activations and weights are packed with *swapped*
halves (the trick that makes a single modular multiply compute a 2-term
dot product):

    a_c = a0 + 2^S * a1          (activation container)
    w_c = w1 + 2^S * w0          (weight container, swapped)

    a_c * w_c  mod 2^B  =  (a0*w0 + a1*w1) * 2^S  +  a0*w1     (mod 2^B)
                            ^^^^^^^^^^^^^^^ dot product ^^^ junk

(the 2^B * a1*w0 term is annihilated by the B-bit modular multiply that
any SEW=B SIMD multiplier performs).

``vmacsr`` (Sparq's custom instruction) computes

    acc <- acc + ((a_c * w_c  mod 2^B) >> S)        [logical shift]

so each issue contributes ``a0*w0 + a1*w1 + floor(a0*w1 / 2^S)`` to the
accumulator.  Within the *overflow-free region* (see ``in_region_*``)
the floor term is zero and the per-issue dot product fits in S bits, so
the accumulation is exact until the B-bit accumulator itself saturates
(after which the kernel must spill into a wider accumulator; the rust
kernel builders schedule those spills, and ``packed_conv2d_ref`` models
an ideal wide accumulator which is what the pallas/TPU adaptation uses).

The *native* (non-vmacsr) ULPPACK scheme instead accumulates the raw
product for ``k_local`` issues and repairs with ``(acc >> S)`` — the
junk field then grows by a0*w1 per issue and both fields must stay
below 2^S, which is exactly the local-accumulation constraint the paper
removes with ``vmacsr``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Container parameterisation: (numpy dtype name, container bits B, shift S).
LP = ("uint16", 16, 8)  # Low-Precision range: 16-bit containers
ULP = ("uint8", 8, 4)  # Ultra-Low-Precision range: 8-bit containers

_DTYPES = {16: jnp.uint16, 8: jnp.uint8}


# ---------------------------------------------------------------------------
# Overflow-free region calculus (mirrored by rust `ulppack::region`)
# ---------------------------------------------------------------------------

def dot_term_max(w_bits: int, a_bits: int) -> int:
    """Worst-case per-issue dot product a0*w0 + a1*w1."""
    return 2 * (2**a_bits - 1) * (2**w_bits - 1)


def junk_term_max(w_bits: int, a_bits: int) -> int:
    """Worst-case per-issue junk term a0*w1."""
    return (2**a_bits - 1) * (2**w_bits - 1)


def in_region_strict(w_bits: int, a_bits: int, shift: int) -> bool:
    """Worst-case-guaranteed overflow-free (vmacsr, single issue)."""
    return dot_term_max(w_bits, a_bits) <= 2**shift - 1


def in_region_paper(w_bits: int, a_bits: int, shift: int) -> bool:
    """The paper's operating region: W + A <= S (Fig. 5).

    For S=8 (LP) this is W+A <= 8 which admits W4A4 (the 1.7x headline);
    for S=4 (ULP) it admits W2A2 (the 3.2x headline).  Inside this
    region the *typical* dot product of LSQ-style quantized tensors fits
    in S bits even though the adversarial worst case does not; see
    EXPERIMENTS.md for measured overflow rates.
    """
    return w_bits + a_bits <= shift


def native_local_accumulations(w_bits: int, a_bits: int, shift: int) -> int:
    """How many raw products the native scheme may accumulate before the
    S-bit dot/junk fields can overflow (worst case).  0 = not possible."""
    d, j = dot_term_max(w_bits, a_bits), junk_term_max(w_bits, a_bits)
    if d == 0:
        return 2**shift - 1
    if d > 2**shift - 1:
        return 0
    return min((2**shift - 1) // d, (2**shift - 1) // max(j, 1))


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------

def pack_activations_ref(levels, container_bits: int) -> jnp.ndarray:
    """Pack unsigned activation levels pairwise along axis 0 (channels).

    levels: (C, H, W) integer array, C even.  Returns (C//2, H, W) of
    uint{container_bits} with ``out[c] = lv[2c] + (lv[2c+1] << S)``.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    lv = jnp.asarray(levels).astype(dt)
    return (lv[0::2] | (lv[1::2] << s)).astype(dt)


def pack_weights_ref(levels, container_bits: int) -> jnp.ndarray:
    """Pack unsigned weight levels pairwise along axis 1 (in-channels),
    with swapped halves: ``out[o, c] = lv[o, 2c+1] + (lv[o, 2c] << S)``.

    levels: (Co, C, Fh, Fw); returns (Co, C//2, Fh, Fw).
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    lv = jnp.asarray(levels).astype(dt)
    return (lv[:, 1::2] | (lv[:, 0::2] << s)).astype(dt)


# ---------------------------------------------------------------------------
# Convolutions
# ---------------------------------------------------------------------------

def conv2d_int_ref(x, w) -> jnp.ndarray:
    """Plain integer 'valid' conv2d, channel-first, int32 accumulation.

    x: (C, H, W) levels; w: (Co, C, Fh, Fw) levels -> (Co, Ho, Wo) int32.
    This is the ground truth every packed implementation must match
    inside its overflow-free region.
    """
    x = jnp.asarray(x).astype(jnp.int32)
    w = jnp.asarray(w).astype(jnp.int32)
    co, c, fh, fw = w.shape
    _, h, wd = x.shape
    ho, wo = h - fh + 1, wd - fw + 1
    out = jnp.zeros((co, ho, wo), jnp.int32)
    for i in range(fh):
        for j in range(fw):
            patch = x[:, i : i + ho, j : j + wo]  # (C, Ho, Wo)
            out = out + jnp.einsum("chw,oc->ohw", patch, w[:, :, i, j])
    return out


def packed_conv2d_ref(xp, wp, container_bits: int) -> jnp.ndarray:
    """vmacsr-dataflow packed conv2d with an ideal wide accumulator.

    xp: (Cp, H, W) packed activations, wp: (Co, Cp, Fh, Fw) packed
    weights (both uint{container_bits}).  Per product:
        contrib = ((xp * wp) mod 2^B) >> S        (logical)
    accumulated in int32 -> (Co, Ho, Wo) int32.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    xp = jnp.asarray(xp).astype(dt)
    wp = jnp.asarray(wp).astype(dt)
    co, cp, fh, fw = wp.shape
    _, h, wd = xp.shape
    ho, wo = h - fh + 1, wd - fw + 1
    acc = jnp.zeros((co, ho, wo), jnp.int32)
    for i in range(fh):
        for j in range(fw):
            patch = xp[:, i : i + ho, j : j + wo]  # (Cp, Ho, Wo)
            # modular multiply at container width, per output channel
            prod = patch[None] * wp[:, :, i, j][:, :, None, None]
            contrib = (prod >> s).astype(jnp.int32)
            acc = acc + contrib.sum(axis=1)
    return acc


def packed_conv2d_hw_ref(xp, wp, container_bits: int, spill_every: int = 0):
    """Hardware-exact vmacsr conv2d: accumulator is *container-width* and
    wraps, with optional periodic spills into an int32 accumulator every
    ``spill_every`` issues (0 = never spill, matching a single
    container-width accumulator register).  Mirrors what the rust
    simulator executes; used by cross-layer equivalence tests.
    """
    dt = np.dtype(f"uint{container_bits}")
    s = container_bits // 2
    xp = np.asarray(xp).astype(dt)
    wp = np.asarray(wp).astype(dt)
    co, cp, fh, fw = wp.shape
    _, h, wd = xp.shape
    ho, wo = h - fh + 1, wd - fw + 1
    wide = np.zeros((co, ho, wo), np.int64)
    narrow = np.zeros((co, ho, wo), dt)
    issues = 0
    with np.errstate(over="ignore"):
        for c in range(cp):
            for i in range(fh):
                for j in range(fw):
                    patch = xp[c, i : i + ho, j : j + wo]
                    prod = (patch[None] * wp[:, c, i, j][:, None, None]).astype(dt)
                    narrow = (narrow + (prod >> s)).astype(dt)
                    issues += 1
                    if spill_every and issues % spill_every == 0:
                        wide += narrow.astype(np.int64)
                        narrow = np.zeros_like(narrow)
    wide += narrow.astype(np.int64)
    return jnp.asarray(wide.astype(np.int32))


def native_packed_conv2d_ref(xp, wp, container_bits: int, k_local: int):
    """Native (no-vmacsr) ULPPACK conv2d: raw products accumulate in a
    container-width register for k_local issues, then are repaired with
    a logical shift and added to an int32 accumulator (the vsrl+vadd
    sequence the paper's Fig. 2 removes).
    """
    dt = np.dtype(f"uint{container_bits}")
    s = container_bits // 2
    xp = np.asarray(xp).astype(dt)
    wp = np.asarray(wp).astype(dt)
    co, cp, fh, fw = wp.shape
    _, h, wd = xp.shape
    ho, wo = h - fh + 1, wd - fw + 1
    wide = np.zeros((co, ho, wo), np.int64)
    local = np.zeros((co, ho, wo), dt)
    n = 0
    with np.errstate(over="ignore"):
        for c in range(cp):
            for i in range(fh):
                for j in range(fw):
                    patch = xp[c, i : i + ho, j : j + wo]
                    prod = (patch[None] * wp[:, c, i, j][:, None, None]).astype(dt)
                    local = (local + prod).astype(dt)
                    n += 1
                    if n % max(k_local, 1) == 0:
                        wide += (local >> s).astype(np.int64)
                        local = np.zeros_like(local)
    wide += (local >> s).astype(np.int64)
    return jnp.asarray(wide.astype(np.int32))


# ---------------------------------------------------------------------------
# Quantization reference
# ---------------------------------------------------------------------------

def quantize_levels_ref(x, bits: int, scale: float) -> jnp.ndarray:
    """Unsigned uniform quantizer: levels = clip(round(x/scale), 0, 2^b-1)."""
    q = jnp.round(jnp.asarray(x, jnp.float32) / jnp.float32(scale))
    return jnp.clip(q, 0, 2**bits - 1).astype(jnp.int32)


def fake_quant_ref(x, bits: int, scale: float) -> jnp.ndarray:
    """Quantize-dequantize (the value a QAT forward pass sees)."""
    lv = quantize_levels_ref(x, bits, scale)
    return lv.astype(jnp.float32) * jnp.float32(scale)
