"""Pallas packed sub-byte conv2d — the paper's compute hot-spot as an L1
kernel, re-thought for TPU (see DESIGN.md §Hardware-Adaptation).

The RVV lane packs two sub-byte operands per 16-bit SIMD element and
uses ``vmacsr`` (multiply, shift right by S, accumulate).  The TPU
analogue implemented here packs two operands per VPU integer lane and
fuses ``(a*w mod 2^B) >> S`` into the accumulation so no intermediate
tile ever round-trips to HBM:

  * grid over output channels — each step keeps the whole packed input
    tile (Cp, H, W) plus one (Ho, Wo) int32 accumulator VMEM-resident
    (the RVV kernel's "output-stationary in the VRF" strategy);
  * the Fh×Fw spatial taps are static python loops (the RVV kernel's
    unrolled ``vslidedown`` reuse becomes static slicing of the resident
    tile — same data reuse, zero extra HBM traffic);
  * the reduction over packed channels is a ``fori_loop`` so the kernel
    scales to any channel count without code bloat.

Accumulation is int32 (the natural TPU VPU width): this keeps the packed
multiply trick (the throughput win) while giving the ideal-wide-
accumulator semantics of ``ref.packed_conv2d_ref``.  The container-width
wrap-around accumulator of the real Sparq register file is modelled by
the rust simulator, not here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_DTYPES = {16: jnp.uint16, 8: jnp.uint8}


def _packed_conv2d_kernel(x_ref, w_ref, o_ref, *, fh, fw, shift, cp):
    """One output channel of the vmacsr-dataflow conv2d.

    x_ref: (Cp, H, W) packed containers, w_ref: (1, Cp, Fh, Fw) packed
    weights, o_ref: (1, Ho, Wo) int32.
    """
    x = x_ref[...]
    w = w_ref[0]
    _, h, wd = x.shape
    ho, wo = h - fh + 1, wd - fw + 1

    def body(c, acc):
        xc = jax.lax.dynamic_index_in_dim(x, c, 0, keepdims=False)  # (H, W)
        wc = jax.lax.dynamic_index_in_dim(w, c, 0, keepdims=False)  # (Fh, Fw)
        for i in range(fh):
            for j in range(fw):
                patch = jax.lax.slice(xc, (i, j), (i + ho, j + wo))
                # modular multiply at container width, then the vmacsr
                # shift — one fused VPU pass per tap
                prod = patch * wc[i, j]
                acc = acc + (prod >> shift).astype(jnp.int32)
        return acc

    acc = jax.lax.fori_loop(0, cp, body, jnp.zeros((ho, wo), jnp.int32))
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("container_bits",))
def packed_conv2d(xp: jax.Array, wp: jax.Array, container_bits: int = 16):
    """Packed 'valid' conv2d, channel-first.

    xp: (Cp, H, W) uint{B} packed activations;
    wp: (Co, Cp, Fh, Fw) uint{B} packed weights (swapped halves);
    returns (Co, Ho, Wo) int32 — equal to ``ref.conv2d_int_ref`` of the
    unpacked levels whenever (W, A) is inside the overflow-free region.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    cp, h, w = xp.shape
    co, cpw, fh, fw = wp.shape
    assert cp == cpw, f"channel mismatch: {cp} vs {cpw}"
    ho, wo = h - fh + 1, w - fw + 1
    return pl.pallas_call(
        functools.partial(_packed_conv2d_kernel, fh=fh, fw=fw, shift=s, cp=cp),
        grid=(co,),
        in_specs=[
            pl.BlockSpec((cp, h, w), lambda o: (0, 0, 0)),
            pl.BlockSpec((1, cp, fh, fw), lambda o: (o, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo), lambda o: (o, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((co, ho, wo), jnp.int32),
        interpret=True,
    )(xp.astype(dt), wp.astype(dt))


@functools.partial(jax.jit, static_argnames=("container_bits", "h_tile"))
def packed_conv2d_tiled(
    xp: jax.Array, wp: jax.Array, container_bits: int = 16, h_tile: int = 8
):
    """Row-tiled variant for inputs too tall for a single VMEM tile.

    The grid is (Co, Ho/h_tile); each step loads an (Cp, h_tile+Fh-1, W)
    input slab — the double-buffered HBM->VMEM schedule a real TPU
    lowering would pipeline.  Requires Ho % h_tile == 0.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    cp, h, w = xp.shape
    co, cpw, fh, fw = wp.shape
    assert cp == cpw, f"channel mismatch: {cp} vs {cpw}"
    ho, wo = h - fh + 1, w - fw + 1
    assert ho % h_tile == 0, f"Ho={ho} not divisible by h_tile={h_tile}"
    slab = h_tile + fh - 1

    def kernel(x_ref, w_ref, o_ref):
        # Input slabs overlap by fh-1 rows, which blocked index maps
        # cannot express; the spec hands us the whole input and we carve
        # the slab out with a dynamic row offset (a real TPU lowering
        # would express this as an overlapping HBM->VMEM DMA schedule).
        r = pl.program_id(1)
        x = jax.lax.dynamic_slice(x_ref[...], (0, r * h_tile, 0), (cp, slab, w))
        wt = w_ref[0]

        def body(c, acc):
            xc = jax.lax.dynamic_index_in_dim(x, c, 0, keepdims=False)
            wc = jax.lax.dynamic_index_in_dim(wt, c, 0, keepdims=False)
            for i in range(fh):
                for j in range(fw):
                    patch = jax.lax.slice(xc, (i, j), (i + h_tile, j + wo))
                    acc = acc + ((patch * wc[i, j]) >> s).astype(jnp.int32)
            return acc

        o_ref[0] = jax.lax.fori_loop(0, cp, body, jnp.zeros((h_tile, wo), jnp.int32))

    return pl.pallas_call(
        kernel,
        grid=(co, ho // h_tile),
        in_specs=[
            pl.BlockSpec((cp, h, w), lambda o, r: (0, 0, 0)),
            pl.BlockSpec((1, cp, fh, fw), lambda o, r: (o, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h_tile, wo), lambda o, r: (o, r, 0)),
        out_shape=jax.ShapeDtypeStruct((co, ho, wo), jnp.int32),
        interpret=True,
    )(xp.astype(dt), wp.astype(dt))
