"""Pallas kernels for ULPPACK P1 packing (k=2 operands per container).

These are the L1 packing kernels: they take unsigned quantization levels
and produce packed containers (see ``ref.py`` for the arithmetic).  They
are written for TPU-style tiling — each grid step owns one output
container channel, so the (2, H, W) input block and the (1, H, W) output
block are VMEM-resident — and run under ``interpret=True`` so the same
HLO executes on the CPU PJRT client the rust runtime uses.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_DTYPES = {16: jnp.uint16, 8: jnp.uint8}


def _pack_act_kernel(x_ref, o_ref, *, shift):
    """One packed channel: o = x[0] | (x[1] << S)."""
    lo = x_ref[0]
    hi = x_ref[1]
    o_ref[0] = lo | (hi << shift)


def _pack_wgt_kernel(w_ref, o_ref, *, shift):
    """One packed in-channel (swapped halves): o = w[:,1] | (w[:,0] << S)."""
    lo = w_ref[:, 1]
    hi = w_ref[:, 0]
    o_ref[:, 0] = lo | (hi << shift)


@functools.partial(jax.jit, static_argnames=("container_bits",))
def pack_activations(levels: jax.Array, container_bits: int = 16) -> jax.Array:
    """Pack (C, H, W) unsigned levels -> (C//2, H, W) containers.

    ``levels`` may be any integer dtype; values must already be within
    [0, 2^S - 1].  Channel c of the output holds input channels (2c,
    2c+1) with 2c in the low half — matching ``ref.pack_activations_ref``
    and the rust `ulppack::pack` module.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    c, h, w = levels.shape
    assert c % 2 == 0, "channel count must be even for k=2 packing"
    lv = levels.astype(dt)
    return pl.pallas_call(
        functools.partial(_pack_act_kernel, shift=s),
        grid=(c // 2,),
        in_specs=[pl.BlockSpec((2, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c // 2, h, w), dt),
        interpret=True,
    )(lv)


@functools.partial(jax.jit, static_argnames=("container_bits",))
def pack_weights(levels: jax.Array, container_bits: int = 16) -> jax.Array:
    """Pack (Co, C, Fh, Fw) unsigned weight levels -> (Co, C//2, Fh, Fw).

    Halves are *swapped* relative to activations (w[2c] lands in the high
    half) so a single modular multiply aligns a0*w0 + a1*w1 in the dot
    field — see ref.py's derivation.
    """
    dt = _DTYPES[container_bits]
    s = container_bits // 2
    co, c, fh, fw = levels.shape
    assert c % 2 == 0, "in-channel count must be even for k=2 packing"
    lv = levels.astype(dt)
    return pl.pallas_call(
        functools.partial(_pack_wgt_kernel, shift=s),
        grid=(c // 2,),
        in_specs=[pl.BlockSpec((co, 2, fh, fw), lambda i: (0, i, 0, 0))],
        out_specs=pl.BlockSpec((co, 1, fh, fw), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((co, c // 2, fh, fw), dt),
        interpret=True,
    )(lv)
