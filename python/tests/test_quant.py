"""Quantizer semantics + gradients (STE) used by the L2 QNN."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

settings.register_profile("sparq", deadline=None, max_examples=25)
settings.load_profile("sparq")


@given(st.integers(1, 8), st.floats(0.01, 2.0), st.integers(0, 2**31 - 1))
def test_act_levels_in_range(bits, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
    lv = np.asarray(quant.quantize_act_levels(x, bits, jnp.float32(scale)))
    assert lv.min() >= 0 and lv.max() <= 2**bits - 1


@given(st.integers(1, 8), st.floats(0.01, 2.0), st.integers(0, 2**31 - 1))
def test_weight_levels_symmetric_range(bits, scale, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
    lv = np.asarray(quant.quantize_weight_levels(w, bits, jnp.float32(scale)))
    zp = 2 ** (bits - 1) - 1
    assert lv.min() >= 0 and lv.max() <= 2 * zp


def test_act_quant_matches_pure_ref():
    x = jnp.linspace(-1, 3, 64)
    lv = quant.quantize_act_levels(x, 3, jnp.float32(0.25))
    want = ref.quantize_levels_ref(np.asarray(x), 3, 0.25)
    assert np.array_equal(np.asarray(lv), np.asarray(want))


def test_fake_quant_act_is_idempotent():
    x = jax.nn.relu(jnp.asarray(np.random.default_rng(0).normal(0, 1, (128,)), jnp.float32))
    s = quant.act_qparams(x, 4)
    y1 = quant.fake_quant_act(x, 4, s)
    y2 = quant.fake_quant_act(y1, 4, s)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_ste_gradient_identity_inside_range():
    s = jnp.float32(0.1)
    x = jnp.asarray([0.05, 0.2, 0.5], jnp.float32)  # all inside [0, s*15]
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant_act(v, 4, s)))(x)
    assert np.allclose(np.asarray(g), 1.0)


def test_ste_gradient_zero_outside_range():
    s = jnp.float32(0.1)
    x = jnp.asarray([-0.5, 5.0], jnp.float32)  # below 0 / above s*(2^4-1)
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant_act(v, 4, s)))(x)
    assert np.allclose(np.asarray(g), 0.0)


def test_weight_ste_gradient_mask():
    s = jnp.float32(0.1)
    zp = 2 ** (4 - 1) - 1
    x = jnp.asarray([0.0, s * zp * 0.5, s * zp * 2.0], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(quant.fake_quant_weight(v, 4, s)))(x)
    assert np.allclose(np.asarray(g), [1.0, 1.0, 0.0])


def test_zero_point_correction_identity():
    """dot(a, q-zp) == dot(a, q) - zp*sum(a): the identity the packed
    forward path relies on (exact, integer)."""
    rng = np.random.default_rng(5)
    bits = 3
    zp = 2 ** (bits - 1) - 1
    a = rng.integers(0, 8, (100,))
    q = rng.integers(0, 2 * zp + 1, (100,))
    lhs = int(np.dot(a, q - zp))
    rhs = int(np.dot(a, q)) - zp * int(a.sum())
    assert lhs == rhs


@given(st.integers(2, 6), st.integers(0, 2**31 - 1))
def test_dequant_error_bounded_by_half_scale(bits, seed):
    rng = np.random.default_rng(seed)
    x = jax.nn.relu(jnp.asarray(rng.normal(0.5, 0.4, (256,)), jnp.float32))
    s = quant.act_qparams(x, bits)
    y = quant.fake_quant_act(x, bits, s)
    inside = np.asarray(x) <= float(s) * (2**bits - 1)
    err = np.abs(np.asarray(y) - np.asarray(x))[inside]
    assert err.max() <= float(s) / 2 + 1e-6
