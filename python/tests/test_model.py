"""L2 model: shapes, training sanity, and QAT-vs-packed-path agreement."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import dataset, model
from compile.kernels import quant


@pytest.fixture(scope="module")
def trained():
    """One small FP32 training run shared by the module's tests."""
    x, y = dataset.make_dataset(512, seed=3)
    params = model.init_params(seed=3)
    params, losses = model.train(params, {}, model.QConfig(None, None), x, y, steps=120)
    return params, x, y, losses


def test_forward_shapes(trained):
    params, x, _, _ = trained
    logits = model.forward_qat(params, {}, model.QConfig(None, None), jnp.asarray(x[:8]))
    assert logits.shape == (8, model.NUM_CLASSES)


def test_training_reduces_loss(trained):
    _, _, _, losses = trained
    assert losses[-1][1] < losses[0][1]


def test_fp32_learns_the_task(trained):
    params, x, y, _ = trained
    acc = model.accuracy(model.forward_qat, params, {}, model.QConfig(None, None), x, y)
    assert acc > 0.9, f"fp32 train accuracy only {acc}"


@pytest.mark.parametrize("wb,ab", [(4, 4), (3, 3)])
def test_packed_path_agrees_with_qat_path(trained, wb, ab):
    """The deployed integer path must predict (almost) the same classes
    as the float fake-quant path it was trained with."""
    params, x, y, _ = trained
    cfg = model.QConfig(wb, ab)
    qstate = model.calibrate(params, cfg, jnp.asarray(x[:128]))
    lq = model.forward_qat(params, qstate, cfg, jnp.asarray(x[:64]))
    lp = model.forward_packed(params, qstate, cfg, jnp.asarray(x[:64]))
    agree = np.mean(np.argmax(np.asarray(lq), 1) == np.argmax(np.asarray(lp), 1))
    assert agree > 0.92, f"W{wb}A{ab} agreement {agree}"


def test_container_selection_matches_paper_mapping():
    assert model.QConfig(2, 2).container_bits == 8  # ULP
    assert model.QConfig(1, 1).container_bits == 8
    assert model.QConfig(3, 3).container_bits == 16  # LP
    assert model.QConfig(4, 4).container_bits == 16


def test_calibrate_returns_positive_scales(trained):
    params, x, _, _ = trained
    qs = model.calibrate(params, model.QConfig(3, 3), jnp.asarray(x[:64]))
    for k, v in qs.items():
        assert float(v) > 0, k


def test_dataset_is_balanced_and_bounded():
    x, y = dataset.make_dataset(400, seed=0)
    assert x.min() >= 0.0 and x.max() <= 1.0
    counts = np.bincount(y, minlength=4)
    assert counts.min() > 50  # roughly balanced


def test_dataset_roundtrip_raw(tmp_path):
    x, y = dataset.make_dataset(10, seed=1)
    p = tmp_path / "t.bin"
    dataset.save_raw(str(p), x, y)
    raw = p.read_bytes()
    assert raw[:4] == b"SPQD"
    n, c, h, w = np.frombuffer(raw[4:20], "<u4")
    assert (n, c, h, w) == (10, 1, 16, 16)
    data = np.frombuffer(raw[20 : 20 + 4 * n * c * h * w], "<f4").reshape(10, 1, 16, 16)
    labels = np.frombuffer(raw[20 + 4 * n * c * h * w :], np.uint8)
    assert np.allclose(data, x) and np.array_equal(labels, y)
