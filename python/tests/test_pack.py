"""Hypothesis sweeps: pallas packing kernels vs the jnp reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ulppack_pack import pack_activations, pack_weights

settings.register_profile("sparq", deadline=None, max_examples=25)
settings.load_profile("sparq")


shapes = st.tuples(
    st.sampled_from([2, 4, 6, 8, 16]),  # C (even)
    st.integers(3, 12),  # H
    st.integers(3, 12),  # W
)


@given(shapes, st.sampled_from([8, 16]), st.integers(0, 2**31 - 1))
def test_pack_activations_matches_ref(shape, bits, seed):
    c, h, w = shape
    s = bits // 2
    rng = np.random.default_rng(seed)
    lv = rng.integers(0, 2**s, (c, h, w))
    got = np.asarray(pack_activations(jnp.asarray(lv), bits))
    want = np.asarray(ref.pack_activations_ref(lv, bits))
    assert got.dtype == want.dtype
    assert np.array_equal(got, want)


@given(
    st.sampled_from([1, 2, 4, 8]),  # Co
    st.sampled_from([2, 4, 8, 16]),  # C
    st.sampled_from([1, 3, 5, 7]),  # F
    st.sampled_from([8, 16]),
    st.integers(0, 2**31 - 1),
)
def test_pack_weights_matches_ref(co, c, f, bits, seed):
    s = bits // 2
    rng = np.random.default_rng(seed)
    lv = rng.integers(0, 2**s, (co, c, f, f))
    got = np.asarray(pack_weights(jnp.asarray(lv), bits))
    want = np.asarray(ref.pack_weights_ref(lv, bits))
    assert np.array_equal(got, want)


@given(shapes, st.sampled_from([8, 16]), st.integers(0, 2**31 - 1))
def test_pack_roundtrip(shape, bits, seed):
    """Unpacking both halves recovers the original levels."""
    c, h, w = shape
    s = bits // 2
    rng = np.random.default_rng(seed)
    lv = rng.integers(0, 2**s, (c, h, w))
    packed = np.asarray(pack_activations(jnp.asarray(lv), bits)).astype(np.uint32)
    lo = packed & (2**s - 1)
    hi = packed >> s
    assert np.array_equal(lo, lv[0::2])
    assert np.array_equal(hi, lv[1::2])


def test_pack_rejects_odd_channels():
    import pytest

    with pytest.raises(AssertionError):
        pack_activations(jnp.zeros((3, 4, 4), jnp.int32), 16)


def test_weight_halves_are_swapped():
    """The defining ULPPACK P1 property: act half order != weight half order."""
    lv = np.arange(2 * 1 * 1 * 1).reshape(1, 2, 1, 1) + 1  # w[:,0]=1, w[:,1]=2
    packed = int(np.asarray(pack_weights(jnp.asarray(lv), 16))[0, 0, 0, 0])
    assert packed == 2 + (1 << 8)  # low half = lv[:,1], high half = lv[:,0]
    av = np.arange(2)[:, None, None] + 1  # a[0]=1, a[1]=2
    packed_a = int(np.asarray(pack_activations(jnp.asarray(av), 16))[0, 0, 0])
    assert packed_a == 1 + (2 << 8)  # low half = lv[0], high half = lv[1]
