"""Overflow-free region calculus — the analytical core of ULPPACK/vmacsr."""

import pytest

from compile.kernels import ref


def test_dot_term_max_formula():
    assert ref.dot_term_max(1, 1) == 2
    assert ref.dot_term_max(2, 2) == 18
    assert ref.dot_term_max(4, 4) == 450
    assert ref.dot_term_max(3, 4) == 210


def test_junk_is_half_of_dot():
    for w in range(1, 5):
        for a in range(1, 5):
            assert ref.dot_term_max(w, a) == 2 * ref.junk_term_max(w, a)


def test_strict_region_lp_matches_paper_condition():
    """Strict worst-case region at S=8 coincides with the paper's
    W+A <= 7 condition over the sub-byte range the paper studies
    (1..4 bits; at extreme asymmetry like W1A7 the exact calculus is
    slightly wider than the paper's linear rule)."""
    for w in range(1, 5):
        for a in range(1, 5):
            assert ref.in_region_strict(w, a, 8) == (w + a <= 7), (w, a)
    # the exact calculus admits the extreme-asymmetry corners
    assert ref.in_region_strict(1, 7, 8) and ref.in_region_strict(7, 1, 8)


def test_paper_region_includes_headline_points():
    # the two headline speedup points: W2A2 on ULP, W4A4 on LP
    assert ref.in_region_paper(2, 2, 4)
    assert ref.in_region_paper(4, 4, 8)
    # and their exclusions
    assert not ref.in_region_paper(3, 2, 4)
    assert not ref.in_region_paper(5, 4, 8)


def test_strict_region_ulp():
    assert ref.in_region_strict(1, 1, 4)
    assert ref.in_region_strict(1, 3, 4)
    assert not ref.in_region_strict(2, 2, 4)  # dot 18 > 15


def test_native_local_accumulations_w1a1_ulp():
    """Paper: ~8 local accumulations for 1-bit on 8-bit containers."""
    k = ref.native_local_accumulations(1, 1, 4)
    assert k == 7  # floor(15/2): the guaranteed-safe count


def test_native_local_accumulations_monotone_in_bits():
    prev = 1 << 30
    for bits in range(1, 4):
        k = ref.native_local_accumulations(bits, bits, 8)
        assert k <= prev
        prev = k


def test_native_zero_outside_region():
    assert ref.native_local_accumulations(4, 4, 8) == 0
    assert ref.native_local_accumulations(2, 2, 4) == 0
