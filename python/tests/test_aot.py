"""AOT bridge: HLO text emission invariants the rust loader depends on."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile.aot import to_hlo_text, standalone_kernel


def test_hlo_text_header_and_tuple_root():
    """Text (not proto) interchange; root must be a tuple so the rust
    side can `to_tuple1()` uniformly."""
    spec = jax.ShapeDtypeStruct((16, 18, 18), jnp.int32)
    wspec = jax.ShapeDtypeStruct((8, 16, 3, 3), jnp.int32)
    lowered = jax.jit(standalone_kernel(16)).lower(spec, wspec)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32[8,16,16]" in text  # output shape present
    assert "ROOT tuple" in text  # tuple-wrapped root (return_tuple=True)


def test_standalone_kernel_is_pure_hlo():
    """interpret=True pallas must lower to plain HLO ops — no custom
    calls the CPU PJRT client can't execute."""
    spec = jax.ShapeDtypeStruct((16, 18, 18), jnp.int32)
    wspec = jax.ShapeDtypeStruct((8, 16, 3, 3), jnp.int32)
    lowered = jax.jit(standalone_kernel(8)).lower(spec, wspec)
    text = to_hlo_text(lowered)
    assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into AOT artifact"


def test_no_elided_constants():
    """The HLO printer must not elide large constants — the rust text
    parser reads `constant({...})` as zeros, silently destroying the
    baked weights (this was a real bug)."""
    import jax.numpy as jnp
    import numpy as np

    big = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)
    fn = lambda x: (x @ big,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 64), jnp.float32))
    text = to_hlo_text(lowered)
    assert "constant({...})" not in text
    assert "..." not in text.split("ENTRY")[1], "elided constant leaked into entry"


@pytest.mark.slow
def test_full_aot_quick_run(tmp_path):
    """End-to-end `make artifacts` in quick mode into a temp dir."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--quick"],
        cwd=root,
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    names = os.listdir(tmp_path)
    for expected in (
        "qnn_fp32.hlo.txt",
        "qnn_w4a4.hlo.txt",
        "qnn_w3a3.hlo.txt",
        "qnn_w2a2.hlo.txt",
        "packed_conv2d_lp.hlo.txt",
        "packed_conv2d_ulp.hlo.txt",
        "testset.bin",
        "manifest.txt",
        "train_log.txt",
    ):
        assert expected in names, expected
    manifest = (tmp_path / "manifest.txt").read_text()
    assert len([l for l in manifest.splitlines() if l.startswith("artifact")]) == 6
