"""Hypothesis sweeps of the L1 packed conv2d pallas kernel.

The contract: inside the *strict* overflow-free region the packed kernel
equals the plain integer conv oracle exactly; everywhere it equals the
packed-arithmetic reference (which is what the hardware computes).
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.packed_conv2d import packed_conv2d, packed_conv2d_tiled
from compile.kernels.ulppack_pack import pack_activations, pack_weights

settings.register_profile("sparq", deadline=None, max_examples=20)
settings.load_profile("sparq")


def _strict_pairs(container_bits):
    s = container_bits // 2
    return [
        (w, a)
        for w in range(1, 5)
        for a in range(1, 5)
        if ref.in_region_strict(w, a, s)
    ]


conv_cases = st.tuples(
    st.sampled_from([2, 4, 8, 16]),  # C
    st.integers(4, 10),  # H
    st.integers(4, 10),  # W
    st.sampled_from([1, 2, 4]),  # Co
    st.sampled_from([1, 3]),  # F
)


@given(conv_cases, st.sampled_from([8, 16]), st.integers(0, 2**31 - 1))
def test_packed_conv_equals_oracle_in_strict_region(case, bits, seed):
    c, h, w, co, f = case
    if f >= h or f >= w:
        return
    s = bits // 2
    rng = np.random.default_rng(seed)
    pairs = _strict_pairs(bits)
    wb, ab = pairs[seed % len(pairs)]
    x = rng.integers(0, 2**ab, (c, h, w))
    wt = rng.integers(0, 2**wb, (co, c, f, f))
    xp = pack_activations(jnp.asarray(x), bits)
    wp = pack_weights(jnp.asarray(wt), bits)
    got = np.asarray(packed_conv2d(xp, wp, bits))
    oracle = np.asarray(ref.conv2d_int_ref(x, wt))
    assert np.array_equal(got, oracle), f"W{wb}A{ab} B{bits}"


@given(conv_cases, st.sampled_from([8, 16]), st.integers(0, 2**31 - 1))
def test_packed_conv_equals_packed_reference_always(case, bits, seed):
    """Even outside the region (arbitrary containers) the pallas kernel
    must match the packed-arithmetic reference bit-exactly."""
    c, h, w, co, f = case
    if f >= h or f >= w:
        return
    rng = np.random.default_rng(seed)
    xp = rng.integers(0, 2**bits, (c // 2 or 1, h, w)).astype(f"uint{bits}")
    wp = rng.integers(0, 2**bits, (co, c // 2 or 1, f, f)).astype(f"uint{bits}")
    got = np.asarray(packed_conv2d(jnp.asarray(xp), jnp.asarray(wp), bits))
    want = np.asarray(ref.packed_conv2d_ref(xp, wp, bits))
    assert np.array_equal(got, want)


@given(st.integers(0, 2**31 - 1))
def test_tiled_variant_matches_untiled(seed):
    rng = np.random.default_rng(seed)
    c, h, w, co, f = 8, 11, 12, 4, 4
    xp = rng.integers(0, 2**16, (c, h, w)).astype(np.uint16)
    wp = rng.integers(0, 2**16, (co, c, f, f)).astype(np.uint16)
    a = np.asarray(packed_conv2d(jnp.asarray(xp), jnp.asarray(wp), 16))
    b = np.asarray(packed_conv2d_tiled(jnp.asarray(xp), jnp.asarray(wp), 16, h_tile=4))
    assert np.array_equal(a, b)


def test_w4a4_paper_mode_on_realistic_data():
    """W4A4 is outside the strict region; with realistic (gaussian-ish,
    symmetric-quantized) tensors the packed result should still match
    the oracle almost everywhere.  This documents the paper-mode bet."""
    rng = np.random.default_rng(3)
    c, h, w, co, f = 16, 12, 12, 8, 3
    # levels concentrated near the middle like LSQ-quantized tensors
    x = np.clip(rng.normal(4, 2.2, (c, h, w)).round(), 0, 15).astype(np.int64)
    wt = np.clip(rng.normal(7, 2.4, (co, c, f, f)).round(), 0, 14).astype(np.int64)
    xp = pack_activations(jnp.asarray(x), 16)
    wp = pack_weights(jnp.asarray(wt), 16)
    got = np.asarray(packed_conv2d(xp, wp, 16))
    oracle = np.asarray(ref.conv2d_int_ref(x, wt))
    agree = np.mean(got == oracle)
    assert agree > 0.95, f"paper-mode agreement too low: {agree}"


def test_hw_ref_spills_never_change_result_in_region():
    """Spill cadence is a performance knob, not a correctness knob,
    inside the strict region (W2A2 @ LP, small reduction)."""
    rng = np.random.default_rng(11)
    x = rng.integers(0, 4, (8, 8, 8))
    wt = rng.integers(0, 4, (2, 8, 3, 3))
    xp = np.asarray(pack_activations(jnp.asarray(x), 16))
    wp = np.asarray(pack_weights(jnp.asarray(wt), 16))
    oracle = np.asarray(ref.conv2d_int_ref(x, wt))
    for spill in (0, 1, 3, 7, 16):
        got = np.asarray(ref.packed_conv2d_hw_ref(xp, wp, 16, spill_every=spill))
        assert np.array_equal(got, oracle), f"spill={spill}"


def test_native_scheme_overflows_exactly_where_calculus_says():
    """Adversarial all-max data: k_local accumulations are safe, and
    k_local+1 must corrupt at least one output (the calculus is tight
    for the junk field at W1A1/ULP)."""
    wb = ab = 1
    k = ref.native_local_accumulations(wb, ab, 4)
    c, f = 32, 3  # plenty of reduction depth
    x = np.ones((c, 6, 6), np.int64)
    wt = np.ones((1, c, f, f), np.int64)
    xp = np.asarray(ref.pack_activations_ref(x, 8))
    wp = np.asarray(ref.pack_weights_ref(wt, 8))
    oracle = np.asarray(ref.conv2d_int_ref(x, wt))
    ok = np.asarray(ref.native_packed_conv2d_ref(xp, wp, 8, k))
    assert np.array_equal(ok, oracle)
    # one more local accumulation overflows the 4-bit dot field
    bad = np.asarray(ref.native_packed_conv2d_ref(xp, wp, 8, k + 1))
    assert not np.array_equal(bad, oracle)
