//! Sub-byte conv2d explorer: sweep every precision pair over a custom
//! workload and print which container/scheme the ULPPACK calculus picks
//! and what it buys — the paper's Fig. 5 as an interactive tool.
//!
//! Run: `cargo run --release --example subbyte_conv2d -- [C] [H] [F]`
//! (defaults: 32 70 7)

use sparq::arch::ProcessorConfig;
use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
use sparq::ulppack::region::{plan_native, plan_vmacsr};
use sparq::ulppack::RegionMode;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<u32> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let c = *args.first().unwrap_or(&32);
    let s = *args.get(1).unwrap_or(&70);
    let f = *args.get(2).unwrap_or(&7);
    let dims = ConvDims { c, h: s, w: s, co: 4, fh: f, fw: f };
    println!("workload: {c}x{s}x{s}, {f}x{f} kernel, {} MACs", dims.macs());

    let sparq = ProcessorConfig::sparq();
    let ara = ProcessorConfig::ara();
    let base = {
        let wl = Workload::random(dims, 8, 8, 1);
        run_conv(&sparq, &wl, ConvVariant::Int16)?.report
    };
    println!("int16 baseline: {} cycles\n", base.stats.cycles);
    println!(
        "{:>5} {:>12} {:>9} {:>12} {:>9}   {}",
        "(W,A)", "native cyc", "speedup", "vmacsr cyc", "speedup", "vmacsr plan"
    );

    for w in 1..=4u32 {
        for a in 1..=4u32 {
            let wl = Workload::random(dims, w, a, (w * 7 + a) as u64);
            let nat = match plan_native(w, a) {
                Some(_) => Some(
                    run_conv(&ara, &wl, ConvVariant::Native { w_bits: w, a_bits: a })?.report,
                ),
                None => None,
            };
            let plan = plan_vmacsr(w, a, dims.issues_per_output(), RegionMode::Paper);
            let vms = match plan {
                Some(_) => Some(
                    run_conv(
                        &sparq,
                        &wl,
                        ConvVariant::Vmacsr { w_bits: w, a_bits: a, mode: RegionMode::Paper },
                    )?
                    .report,
                ),
                None => None,
            };
            let plan_str = plan
                .map(|p| {
                    format!(
                        "{} spill@{}{}",
                        p.container.name(),
                        if p.spill_every == u64::MAX {
                            "never".to_string()
                        } else {
                            p.spill_every.to_string()
                        },
                        if p.exact { "" } else { " [paper-mode]" }
                    )
                })
                .unwrap_or_else(|| "--".into());
            let fmt = |r: &Option<sparq::sim::RunReport>| match r {
                Some(r) => (
                    r.stats.cycles.to_string(),
                    format!("{:.2}x", base.stats.cycles as f64 / r.stats.cycles as f64),
                ),
                None => ("--".into(), "--".into()),
            };
            let (nc, ns) = fmt(&nat);
            let (vc, vs) = fmt(&vms);
            println!("W{w}A{a} {nc:>13} {ns:>9} {vc:>12} {vs:>9}   {plan_str}");
        }
    }
    Ok(())
}
