//! ISA explorer: emit the inner loop of Algorithm 1 as a real
//! instruction trace, show its machine encodings, and round-trip them
//! through the decoder — what the paper's Fig. 3 describes, executable.
//!
//! Run: `cargo run --release --example isa_explorer`

use sparq::isa::{decode, disasm, encode, Lmul, ScalarKind, Sew, VInst, VOp};

fn main() {
    // the inner loop of Algorithm 1 for one (channel, kernel-column)
    // iteration at Fh = 3: three vmacsr issues + one slide
    let inner: Vec<VInst> = vec![
        VInst::SetVl { avl: 256, sew: Sew::E16, lmul: Lmul::M4 },
        VInst::Load { eew: Sew::E16, vd: 12, addr: 0x8000 },
        VInst::Scalar { kind: ScalarKind::WeightLoad, n: 1 },
        VInst::OpVX { op: VOp::Macsr, vd: 0, vs2: 12, rs1: 0x0102 },
        VInst::Scalar { kind: ScalarKind::WeightLoad, n: 1 },
        VInst::OpVX { op: VOp::Macsr, vd: 4, vs2: 12, rs1: 0x0201 },
        VInst::Scalar { kind: ScalarKind::WeightLoad, n: 1 },
        VInst::OpVX { op: VOp::Macsr, vd: 8, vs2: 12, rs1: 0x0303 },
        VInst::OpVI { op: VOp::SlideDown, vd: 12, vs2: 12, imm: 1 },
    ];

    println!("Algorithm 1 inner loop (Fh=3), as trace + machine code:\n");
    println!("{:<10} {:<44} {}", "word", "assembly", "decoded-back");
    for inst in &inner {
        let word = encode(inst).expect("inner loop is fully encodable");
        let back = decode(word)
            .map(|i| disasm(&i))
            .unwrap_or_else(|e| format!("<{e}>"));
        println!("{word:#010x} {:<44} {back}", disasm(inst));
    }

    println!("\nkey encodings (paper Fig. 3):");
    for (label, inst) in [
        ("vmacc.vx  (RVV 1.0, funct6=101101)", VInst::OpVX { op: VOp::Macc, vd: 1, vs2: 2, rs1: 0 }),
        ("vmacsr.vx (Sparq,   funct6=101110)", VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 0 }),
        ("vmacsr.vv (Sparq,   OPMVV form)", VInst::OpVV { op: VOp::Macsr, vd: 1, vs2: 2, vs1: 3 }),
        (
            "vmacsr.cfg (this repo's future-work ext)",
            VInst::OpVX { op: VOp::MacsrCfg, vd: 1, vs2: 2, rs1: 0 },
        ),
    ] {
        let w = encode(&inst).expect("fig-3 encodings exist");
        println!("  {w:#010x}  funct6={:06b}  {label}", w >> 26);
    }

    println!("\nillegal-word handling (the dispatcher must trap):");
    for word in [0xffff_ffffu32, (0b111111 << 26) | (1 << 25) | (0b010 << 12) | 0x57] {
        match decode(word) {
            Ok(i) => println!("  {word:#010x}  {}", disasm(&i)),
            Err(e) => println!("  {word:#010x}  trap: {e}"),
        }
    }
}
