//! END-TO-END DRIVER: serve the whole SparqCNN through the simulated
//! dataflow backend.
//!
//! The network compiles ONCE per precision into a chained multi-layer
//! program (`qnn::compiled::CompiledQnn`): one planned activation
//! arena, per-layer convs whose inputs rebind to the previous layer's
//! output region, zero-padding/requantize/maxpool/GAP+FC as real
//! instruction streams, cached in the shared `ProgramCache` under a
//! graph-level key.  The serving coordinator (bounded queue, dynamic
//! batcher, worker threads) classifies a synthetic test set through it
//! — and because the executed network is bit-exact against the host
//! golden model (`QnnNet::golden_forward`), served accuracy against
//! golden labels must be 100%.
//!
//! No artifacts needed: `cargo run --release --example e2e_qnn_serve`

use sparq::config::ServeConfig;
use sparq::coordinator::{sim_qnn_factory, Server};
use sparq::kernels::ProgramCache;
use sparq::power::LaneReport;
use sparq::qnn::schedule::{schedule_seeded, QnnPrecision, DEFAULT_QNN_SEED};
use sparq::qnn::{QnnGraph, QnnNet};
use sparq::sim::MachinePool;
use sparq::ProcessorConfig;
use std::sync::Arc;

const IMAGES: usize = 96;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let graph = QnnGraph::sparq_cnn();
    graph.validate()?;
    let sparq_cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&sparq_cfg).fmax_ghz();
    let cache = Arc::new(ProgramCache::new());
    let pool = MachinePool::new();
    let seed = DEFAULT_QNN_SEED;

    // int16 reference: every conv layer scheduled as int16 (the
    // paper's speedup denominator; pool/head identical across both)
    let int16_cycles = {
        use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
        let mut total = 0u64;
        for (c, co, h, f) in [(2u32, 16u32, 16u32, 3u32), (16, 32, 16, 3), (32, 32, 8, 3)] {
            let dims = ConvDims { c, h: h + f - 1, w: h + f - 1, co, fh: f, fw: f };
            let wl = Workload::random(dims, 8, 8, 1);
            total += run_conv(&sparq_cfg, &wl, ConvVariant::Int16)?.report.stats.cycles;
        }
        total
    };

    let mut summary = Vec::new();
    for prec in [
        QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
        QnnPrecision::SubByte { w_bits: 3, a_bits: 3 },
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
    ] {
        let sched = schedule_seeded(&sparq_cfg, &graph, prec, seed, &cache, &pool)?;
        let cyc = sched.total_cycles();
        println!("=== serving SparqCNN at {} ({cyc} cycles/image, end-to-end) ===", prec.label());
        print!("{}", sparq::report::render_schedule(&sched, fmax));

        // synthetic test set labelled by the golden network: served
        // classifications must agree on every image (bit-exactness)
        let net = QnnNet::from_seed(&graph, prec, seed)?;
        let images: Vec<Vec<u64>> = (0..IMAGES).map(|i| net.test_image(1000 + i as u64)).collect();
        let labels: Vec<usize> = images
            .iter()
            .map(|img| net.golden_forward(img).map(|t| t.argmax))
            .collect::<Result<_, _>>()?;

        let server = Server::start(
            sim_qnn_factory(
                sparq_cfg.clone(),
                graph.clone(),
                prec,
                4,
                seed,
                Arc::clone(&cache),
            ),
            ServeConfig { workers: 2, batch_window_us: 300, queue_depth: 256, ..Default::default() },
            cyc,
        )?;

        let t0 = std::time::Instant::now();
        let mut pending = Vec::new();
        let mut correct = 0usize;
        let mut served = 0usize;
        for (i, img) in images.iter().enumerate() {
            let fimg: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            match server.submit(fimg) {
                Ok(rx) => pending.push((i, rx)),
                Err(e) => println!("request {i}: {e}"),
            }
            if pending.len() >= 32 {
                for (j, rx) in pending.drain(..) {
                    if let Ok(Ok(r)) = rx.recv() {
                        served += 1;
                        correct += (r.class == labels[j]) as usize;
                    }
                }
            }
        }
        for (j, rx) in pending.drain(..) {
            if let Ok(Ok(r)) = rx.recv() {
                served += 1;
                correct += (r.class == labels[j]) as usize;
            }
        }
        let wall = t0.elapsed();
        let snap = server.shutdown();
        let acc = correct as f64 / served.max(1) as f64;
        let speedup = int16_cycles as f64 / cyc as f64;
        println!(
            "  golden agreement {:.2}% over {} images (must be 100 — the arena numerics are exact)\n  \
             latency p50/p95/p99 = {}/{}/{} us, mean batch {:.1}, {:.0} req/s (wall {:.2}s)\n  \
             hardware: {} cycles/image -> {:.0} img/s at {:.3} GHz; speedup over int16 convs: {:.2}x\n",
            100.0 * acc,
            served,
            snap.p50_us,
            snap.p95_us,
            snap.p99_us,
            snap.mean_batch,
            snap.throughput_rps,
            wall.as_secs_f64(),
            cyc,
            sched.throughput_at(fmax),
            fmax,
            speedup
        );
        summary.push((prec.label(), acc, cyc, speedup));
    }

    // === the batched request path (DESIGN.md §Serving) ===
    // The same W2A2 network compiled under the batch-4 arena layout:
    // sharded submission queues, one batched execution per window, the
    // per-batch weight-pack preamble amortized across the fill.  Served
    // classifications stay bit-exact against the golden network.
    {
        use sparq::coordinator::QnnBatchServer;
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let serve = ServeConfig {
            workers: 2,
            batch_window_us: 2_000,
            queue_depth: 256,
            batch: 4,
            ..ServeConfig::default()
        };
        let server =
            QnnBatchServer::start(sparq_cfg.clone(), &graph, prec, seed, serve, &cache)?;
        let net = QnnNet::from_seed(&graph, prec, seed)?;
        let images: Vec<Vec<u64>> = (0..IMAGES).map(|i| net.test_image(2000 + i as u64)).collect();
        let labels: Vec<usize> = images
            .iter()
            .map(|img| net.golden_forward(img).map(|t| t.argmax))
            .collect::<Result<_, _>>()?;
        let mut pending = Vec::new();
        for (i, img) in images.iter().enumerate() {
            let fimg: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            match server.submit(fimg) {
                Ok(rx) => pending.push((i, rx)),
                Err(e) => println!("request {i}: {e}"),
            }
        }
        let mut correct = 0usize;
        let mut served = 0usize;
        for (j, rx) in pending {
            if let Ok(Ok(r)) = rx.recv() {
                served += 1;
                correct += (r.class == labels[j]) as usize;
            }
        }
        // every request must actually serve — a skipped/errored request
        // would make the agreement check below vacuous
        assert_eq!(served, IMAGES, "batched serving dropped requests");
        let snap = server.shutdown();
        let fills: Vec<String> =
            snap.batch_fill.iter().map(|&(k, c)| format!("{k}x{c}")).collect();
        println!(
            "=== batched serving (batch-4 arena, 2 shard workers) ===\n  \
             golden agreement {:.2}% over {served} images (must be 100)\n  \
             {} batches (fill histogram: {}), queue depth max {}\n  \
             latency p50/p99 = {}/{} us | p50/p99 = {}/{} simulated cycles\n",
            100.0 * correct as f64 / served.max(1) as f64,
            snap.batches,
            fills.join(" "),
            snap.queue_depth_max,
            snap.p50_us,
            snap.p99_us,
            snap.p50_cycles,
            snap.p99_cycles,
        );
        assert_eq!(correct, served, "batched serving must agree with the golden network");
    }

    let cs = cache.stats();
    println!("=== summary (paper headline: 3.2x @ 2-bit, 1.7x @ 4-bit on conv2d) ===");
    println!(
        "{:<10} {:>17} {:>14} {:>22}",
        "model", "golden agreement", "cycles/image", "speedup vs int16 convs"
    );
    for (m, acc, cyc, sp) in &summary {
        println!("{:<10} {:>16.2}% {:>14} {:>21.2}x", m, 100.0 * acc, cyc, sp);
    }
    println!(
        "program cache: {} network compile(s), {} hits across scheduling + serving",
        cs.misses, cs.hits
    );
    Ok(())
}
