//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! * L1/L2 (build time): `make artifacts` trained the QNN and lowered
//!   the packed pallas conv + model to HLO text.
//! * Runtime (this binary, pure rust): load the artifacts via PJRT,
//!   stand up the serving coordinator (bounded queue, dynamic batcher,
//!   worker threads), stream the held-out test set through it, and
//!   attribute simulated Sparq hardware cycles to every request via the
//!   qnn scheduler.
//!
//! Reports: accuracy per precision (Table I), serving latency
//! percentiles + throughput, and the paper's headline metric — the
//! sub-byte speedup over the int16 schedule.  Results are recorded in
//! EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_qnn_serve`

use sparq::config::ServeConfig;
use sparq::coordinator::{Executor, PjrtExecutor, Server};
use sparq::power::LaneReport;
use sparq::qnn::schedule::QnnPrecision;
use sparq::report;
use sparq::runtime::{artifacts_dir, artifacts_present, TestSet};
use sparq::ProcessorConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if !artifacts_present() {
        eprintln!("no artifacts found — run `make artifacts` first");
        std::process::exit(2);
    }
    let dir = artifacts_dir();
    let ts = TestSet::load(dir.join("testset.bin"))?;
    println!(
        "test set: {} images ({}x{}x{}), 4 classes\n",
        ts.n, ts.c, ts.h, ts.w
    );

    let sparq_cfg = ProcessorConfig::sparq();
    let fmax = LaneReport::for_config(&sparq_cfg).fmax_ghz();
    let int16_sched =
        report::qnn_schedule(&sparq_cfg, QnnPrecision::SubByte { w_bits: 8, a_bits: 8 });
    // int16 reference: schedule the quantized layers as int16 too
    let int16_cycles = {
        use sparq::kernels::{run_conv, ConvDims, ConvVariant, Workload};
        // conv1 + conv2 + conv3 all as int16 (padded dims, as scheduler)
        let mut total = 0u64;
        for (c, co, h, f) in [(2u32, 16u32, 16u32, 3u32), (16, 32, 16, 3), (32, 32, 8, 3)] {
            let dims = ConvDims { c, h: h + f - 1, w: h + f - 1, co, fh: f, fw: f };
            let wl = Workload::random(dims, 8, 8, 1);
            total += run_conv(&sparq_cfg, &wl, ConvVariant::Int16)?.report.stats.cycles;
        }
        total
    };
    drop(int16_sched);

    let mut summary = Vec::new();
    for (model, prec) in [
        ("qnn_w4a4", QnnPrecision::SubByte { w_bits: 4, a_bits: 4 }),
        ("qnn_w3a3", QnnPrecision::SubByte { w_bits: 3, a_bits: 3 }),
        ("qnn_w2a2", QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }),
    ] {
        let sched = report::qnn_schedule(&sparq_cfg, prec)?;
        let cyc = sched.total_cycles();
        println!("=== serving {model} (simulated Sparq: {cyc} cycles/image) ===");

        let dirc = dir.clone();
        let modelc = model.to_string();
        let server = Server::start(
            Box::new(move || {
                Ok(Box::new(PjrtExecutor::new(&dirc, &modelc)?) as Box<dyn Executor>)
            }),
            ServeConfig { workers: 2, batch_window_us: 300, queue_depth: 256 },
            cyc,
        )?;

        let t0 = std::time::Instant::now();
        type Rx = std::sync::mpsc::Receiver<
            Result<sparq::coordinator::InferResult, sparq::coordinator::ServeError>,
        >;
        let mut pending: Vec<(usize, Rx)> = Vec::new();
        let mut correct = 0usize;
        let mut served = 0usize;
        for i in 0..ts.n {
            // cap in-flight work so reported latency reflects service
            // time + batching, not a self-inflicted standing queue
            if pending.len() >= 32 {
                for (j, rx) in pending.drain(..) {
                    if let Ok(Ok(r)) = rx.recv() {
                        served += 1;
                        correct += (r.class == ts.labels[j] as usize) as usize;
                    }
                }
            }
            match server.submit(ts.image(i).to_vec()) {
                Ok(rx) => pending.push((i, rx)),
                Err(_) => {
                    // backpressure: drain, then retry once
                    for (j, rx) in pending.drain(..) {
                        if let Ok(Ok(r)) = rx.recv() {
                            served += 1;
                            correct += (r.class == ts.labels[j] as usize) as usize;
                        }
                    }
                    if let Ok(rx) = server.submit(ts.image(i).to_vec()) {
                        pending.push((i, rx));
                    }
                }
            }
        }
        for (j, rx) in pending.drain(..) {
            if let Ok(Ok(r)) = rx.recv() {
                served += 1;
                correct += (r.class == ts.labels[j] as usize) as usize;
            }
        }
        let wall = t0.elapsed();
        let snap = server.shutdown();
        let acc = correct as f64 / served.max(1) as f64;
        let speedup = int16_cycles as f64 / cyc as f64;
        println!(
            "  accuracy {:.2}% over {} images\n  \
             latency p50/p95/p99 = {}/{}/{} us, mean batch {:.1}, {:.0} req/s (wall {:.2}s)\n  \
             hardware: {} cycles/image -> {:.0} img/s at {:.3} GHz; speedup over int16 schedule: {:.2}x\n",
            100.0 * acc,
            served,
            snap.p50_us,
            snap.p95_us,
            snap.p99_us,
            snap.mean_batch,
            snap.throughput_rps,
            wall.as_secs_f64(),
            cyc,
            sched.throughput_at(fmax),
            fmax,
            speedup
        );
        summary.push((model, acc, cyc, speedup));
    }

    println!("=== summary (headline: paper claims 3.2x @ 2-bit, 1.7x @ 4-bit on conv2d) ===");
    println!("{:<10} {:>9} {:>14} {:>22}", "model", "accuracy", "cycles/image", "speedup vs int16 QNN");
    for (m, acc, cyc, sp) in &summary {
        println!("{:<10} {:>8.2}% {:>14} {:>21.2}x", m, 100.0 * acc, cyc, sp);
    }
    Ok(())
}
