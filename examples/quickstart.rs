//! Quickstart: pack a sub-byte tensor, run the vmacsr conv2d on the
//! simulated Sparq, verify against the integer oracle, and compare
//! against the int16 baseline — the paper's core claim in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use sparq::arch::{ProcessorConfig, Unit};
use sparq::kernels::{run_conv, workload, ConvDims, ConvVariant, Workload};
use sparq::ulppack::RegionMode;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // a W2A2-quantized 7x7 convolution over a 16-channel image
    let dims = ConvDims { c: 16, h: 38, w: 38, co: 4, fh: 7, fw: 7 };
    println!(
        "workload: {}x{}x{} -> {} channels, {}x{} kernel ({} MACs)\n",
        dims.c, dims.h, dims.w, dims.co, dims.fh, dims.fw, dims.macs()
    );

    // 1. the accelerated path: ULPPACK + vmacsr on Sparq
    let wl = Workload::random(dims, 2, 2, 7);
    let sparq = ProcessorConfig::sparq();
    let run = run_conv(
        &sparq,
        &wl,
        ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict },
    )?;
    println!("{}:", run.report.label);
    println!(
        "  {} cycles, {:.2} ops/cycle, MFPU {:.1}% busy",
        run.report.stats.cycles,
        run.report.ops_per_cycle(),
        100.0 * run.report.stats.utilization(Unit::Mfpu)
    );

    // 2. bit-exact against the plain integer convolution oracle
    let got = run.out.read_ints(&run.machine.mem)?;
    assert_eq!(got, workload::golden_exact(&wl), "packed conv must be exact in-region");
    println!("  output verified against the integer conv oracle OK");

    // 3. the baseline the paper compares against
    let wl16 = Workload::random(dims, 8, 8, 7);
    let base = run_conv(&sparq, &wl16, ConvVariant::Int16)?;
    println!("\n{}:", base.report.label);
    println!(
        "  {} cycles, {:.2} ops/cycle",
        base.report.stats.cycles,
        base.report.ops_per_cycle()
    );

    println!(
        "\nspeedup: {:.2}x (paper's W2A2 headline: 3.2x on the full-size workload)",
        run.report.speedup_over(&base.report)
    );

    // 4. what the custom instruction looks like on the wire
    use sparq::isa::{encode, VInst, VOp};
    let word = encode(&VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 0 })?;
    println!(
        "\nvmacsr.vx v1, v2, a0  encodes as {word:#010x} (funct6 = 0b101110, the slot after vmacc)"
    );
    Ok(())
}
